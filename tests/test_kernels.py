"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


# ----------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 512, 4, 1, 128),    # MQA
    (1, 192, 6, 2, 32),     # ragged seq (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention(B, S, H, KV, hd, dtype, causal, window):
    k0 = jax.random.PRNGKey(42)
    q = rand(jax.random.fold_in(k0, 0), (B, S, H, hd), dtype)
    k = rand(jax.random.fold_in(k0, 1), (B, S, KV, hd), dtype)
    v = rand(jax.random.fold_in(k0, 2), (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **TOL[dtype])


@pytest.mark.parametrize("B,L,H,KV,hd,n_splits", [
    (2, 256, 8, 2, 64, 4),
    (1, 512, 4, 4, 128, 8),
    (3, 128, 4, 1, 64, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, L, H, KV, hd, n_splits, dtype):
    k0 = jax.random.PRNGKey(7)
    q = rand(jax.random.fold_in(k0, 0), (B, H, hd), dtype)
    k = rand(jax.random.fold_in(k0, 1), (B, L, KV, hd), dtype)
    v = rand(jax.random.fold_in(k0, 2), (B, L, KV, hd), dtype)
    lengths = jax.random.randint(jax.random.fold_in(k0, 3), (B,), 1, L + 1)
    out = ops.decode_attention(q, k, v, lengths, n_splits=n_splits,
                               interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **TOL[dtype])


@pytest.mark.parametrize("B,nb_seq,bs,H,KV,hd", [
    (2, 4, 16, 8, 2, 64),    # GQA 4:1
    (1, 3, 32, 4, 4, 128),   # MHA
    (3, 5, 8, 4, 1, 64),     # MQA, small blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, nb_seq, bs, H, KV, hd, dtype):
    """Kernel gathers K/V through a shuffled block table; must match the
    gather-then-attend reference on the same pool."""
    k0 = jax.random.PRNGKey(13)
    num_blocks = B * nb_seq + 1                 # + reserved null block 0
    q = rand(jax.random.fold_in(k0, 0), (B, H, hd), dtype)
    kp = rand(jax.random.fold_in(k0, 1), (num_blocks, bs, KV, hd), dtype)
    vp = rand(jax.random.fold_in(k0, 2), (num_blocks, bs, KV, hd), dtype)
    # each sequence owns a random disjoint set of physical blocks, in a
    # scrambled order — exactly what a long-lived allocator produces
    perm = np.asarray(jax.random.permutation(jax.random.fold_in(k0, 3),
                                             num_blocks - 1)) + 1
    bt = jnp.asarray(perm.reshape(B, nb_seq), jnp.int32)
    lengths = jax.random.randint(jax.random.fold_in(k0, 4), (B,), 1,
                                 nb_seq * bs + 1)
    out = ops.paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **TOL[dtype])


def test_paged_decode_matches_dense_decode():
    """A paged cache holding the same tokens as a dense cache produces the
    same attention output (the paged engine's parity in miniature)."""
    k0 = jax.random.PRNGKey(21)
    B, L, H, KV, hd, bs = 2, 64, 4, 2, 32, 16
    nb = L // bs
    q = rand(jax.random.fold_in(k0, 0), (B, H, hd), jnp.float32)
    k = rand(jax.random.fold_in(k0, 1), (B, L, KV, hd), jnp.float32)
    v = rand(jax.random.fold_in(k0, 2), (B, L, KV, hd), jnp.float32)
    lengths = jnp.asarray([L, 23])
    # scatter the dense caches into a pool, sequences interleaved
    kp = jnp.concatenate([jnp.zeros((1, bs, KV, hd))] +
                         [k[b, j * bs:(j + 1) * bs][None]
                          for j in range(nb) for b in range(B)])
    vp = jnp.concatenate([jnp.zeros((1, bs, KV, hd))] +
                         [v[b, j * bs:(j + 1) * bs][None]
                          for j in range(nb) for b in range(B)])
    bt = jnp.asarray([[1 + j * B + b for j in range(nb)]
                      for b in range(B)], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,nb_seq,bs,H,KV,hd", [
    (2, 4, 4, 16, 8, 2, 64),    # GQA 4:1 (internlm2-style heads)
    (1, 8, 3, 32, 4, 4, 128),   # MHA (gemma-style KV=H)
    (3, 3, 5, 8, 4, 1, 64),     # MQA, small blocks, odd suffix
    (2, 16, 2, 16, 8, 2, 64),   # suffix spanning whole blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_extend_attention(B, S, nb_seq, bs, H, KV, hd, dtype):
    """Extend kernel: S suffix queries at absolute positions pos0+s attend
    through a shuffled block table; must match the gather-then-attend
    reference (dense-extend mask over absolute positions)."""
    k0 = jax.random.PRNGKey(17)
    num_blocks = B * nb_seq + 1
    q = rand(jax.random.fold_in(k0, 0), (B, S, H, hd), dtype)
    kp = rand(jax.random.fold_in(k0, 1), (num_blocks, bs, KV, hd), dtype)
    vp = rand(jax.random.fold_in(k0, 2), (num_blocks, bs, KV, hd), dtype)
    perm = np.asarray(jax.random.permutation(jax.random.fold_in(k0, 3),
                                             num_blocks - 1)) + 1
    bt = jnp.asarray(perm.reshape(B, nb_seq), jnp.int32)
    # pos0 anywhere the suffix still fits in the table's span — including
    # 0 (pure prefill) when it does
    pos0 = jax.random.randint(jax.random.fold_in(k0, 4), (B,), 0,
                              nb_seq * bs - S + 1)
    out = ops.paged_extend_attention(q, kp, vp, bt, pos0, interpret=True)
    want = ref.paged_extend_attention_ref(q, kp, vp, bt, pos0)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **TOL[dtype])


def test_paged_extend_matches_dense_flash_prefill():
    """With pos0=0 and the suffix covering the whole sequence, the paged
    extend kernel is causal prefill: it must match the dense flash oracle
    on the same tokens scattered into a pool."""
    k0 = jax.random.PRNGKey(23)
    B, S, H, KV, hd, bs = 2, 64, 4, 2, 32, 16
    nb = S // bs
    q = rand(jax.random.fold_in(k0, 0), (B, S, H, hd), jnp.float32)
    k = rand(jax.random.fold_in(k0, 1), (B, S, KV, hd), jnp.float32)
    v = rand(jax.random.fold_in(k0, 2), (B, S, KV, hd), jnp.float32)
    kp = jnp.concatenate([jnp.zeros((1, bs, KV, hd))] +
                         [k[b, j * bs:(j + 1) * bs][None]
                          for j in range(nb) for b in range(B)])
    vp = jnp.concatenate([jnp.zeros((1, bs, KV, hd))] +
                         [v[b, j * bs:(j + 1) * bs][None]
                          for j in range(nb) for b in range(B)])
    bt = jnp.asarray([[1 + j * B + b for j in range(nb)]
                      for b in range(B)], jnp.int32)
    pos0 = jnp.zeros((B,), jnp.int32)
    out = ops.paged_extend_attention(q, kp, vp, bt, pos0, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("N,M,d", [(64, 128, 256), (100, 60, 128),
                                   (128, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pair_score(N, M, d, dtype):
    k0 = jax.random.PRNGKey(3)
    claims = rand(jax.random.fold_in(k0, 0), (N, d), dtype)
    evid = rand(jax.random.fold_in(k0, 1), (M, d), dtype)
    W = rand(jax.random.fold_in(k0, 2), (d, d), jnp.float32) / np.sqrt(d)
    w = rand(jax.random.fold_in(k0, 3), (2 * d,), jnp.float32)
    params = {"W": W, "w": w, "bias": jnp.asarray(0.3)}
    out = ops.pair_score(params, claims, evid, block_n=32, block_m=64,
                         interpret=True)
    want = ref.pair_score_ref(claims, evid, W, w[:d], w[d:], 0.3)
    # accumulation-order differences grow with d; scores are O(sqrt(d))
    tol = dict(atol=5e-4 * np.sqrt(d), rtol=5e-3) \
        if dtype == jnp.float32 else TOL[jnp.bfloat16]
    np.testing.assert_allclose(out, want, **tol)


@pytest.mark.parametrize("B,S,D,N,chunk", [
    (1, 128, 64, 8, 32),
    (2, 100, 128, 16, 64),   # pad path
    (1, 256, 512, 16, 64),
])
def test_ssm_scan(B, S, D, N, chunk):
    k0 = jax.random.PRNGKey(11)
    # realistic stable dynamics: a in (0,1), b small
    a = jax.nn.sigmoid(rand(jax.random.fold_in(k0, 0), (B, S, D, N),
                            jnp.float32))
    b = rand(jax.random.fold_in(k0, 1), (B, S, D, N), jnp.float32) * 0.1
    h0 = rand(jax.random.fold_in(k0, 2), (B, D, N), jnp.float32)
    from repro.kernels.ssm_scan import ssm_scan_blocked
    hs, hT = ssm_scan_blocked(a, b, h0, chunk=chunk, block_d=min(64, D),
                              interpret=True)
    want_hs, want_hT = ref.ssm_scan_ref(a, b, h0)
    np.testing.assert_allclose(hs, want_hs, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hT, want_hT, atol=1e-4, rtol=1e-4)


def test_ssm_ops_matches_model_scan():
    """kernels.ops.ssm_scan == models.ssm.selective_scan on random data."""
    from repro.models.ssm import selective_scan
    k0 = jax.random.PRNGKey(5)
    B, S, D, N = 2, 96, 64, 8
    xc = rand(jax.random.fold_in(k0, 0), (B, S, D), jnp.float32)
    dt = jax.nn.softplus(rand(jax.random.fold_in(k0, 1), (B, S, D), jnp.float32))
    Bc = rand(jax.random.fold_in(k0, 2), (B, S, N), jnp.float32)
    Cc = rand(jax.random.fold_in(k0, 3), (B, S, N), jnp.float32)
    A = -jnp.exp(rand(jax.random.fold_in(k0, 4), (D, N), jnp.float32))
    Dd = rand(jax.random.fold_in(k0, 5), (D,), jnp.float32)
    y_k, h_k = ops.ssm_scan(xc, dt, Bc, Cc, A, Dd, chunk=32,
                            block_d=32, interpret=True)
    y_r, h_r = selective_scan(xc, dt, Bc, Cc, A, Dd, chunk=16)
    np.testing.assert_allclose(y_k, y_r, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(h_k, h_r, atol=1e-3, rtol=1e-3)


def test_flash_kernel_matches_model_flash():
    """Pallas flash == the model's chunked-jnp flash (the dry-run path)."""
    from repro.models.attention import flash_attention_jnp
    k0 = jax.random.PRNGKey(9)
    B, S, H, KV, hd = 1, 256, 8, 4, 64
    q = rand(jax.random.fold_in(k0, 0), (B, S, H, hd), jnp.float32)
    k = rand(jax.random.fold_in(k0, 1), (B, S, KV, hd), jnp.float32)
    v = rand(jax.random.fold_in(k0, 2), (B, S, KV, hd), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                            interpret=True)
    b = flash_attention_jnp(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_pair_kernel_matches_pipeline_linkscore():
    """Pallas pair_score == svm.link_score_matrix (phase-2 oracle)."""
    from repro.models import svm as svm_mod
    from repro.core.sharding import split_params
    d = 128
    params, _ = split_params(
        {"link": svm_mod.init_link(jax.random.PRNGKey(1), d)})
    link = params["link"]
    claims = jax.random.normal(jax.random.PRNGKey(2), (96, d))
    evid = jax.random.normal(jax.random.PRNGKey(3), (64, d))
    a = ops.pair_score(link, claims, evid, block_n=32, block_m=32,
                       interpret=True)
    b = svm_mod.link_score_matrix(link, claims, evid)
    np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)
