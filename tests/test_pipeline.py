"""The paper's two-phase pipeline: correctness vs brute force + properties."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.filtering import compact_by_score
from repro.core.pipeline import (PipelineConfig, batch_step_local,
                                 extract_links, init_models, make_batch_step)
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus
from repro.models import svm as svm_mod


PCFG = PipelineConfig(feat_dim=256, claim_capacity=96, evid_capacity=192)


def brute_force_links(models, X, keys, pcfg):
    """Reference semantics: the paper's filter + per-doc Cartesian join."""
    kw = dict(gamma=pcfg.svm_gamma, coef0=pcfg.svm_coef0, degree=pcfg.svm_degree)
    c_sc = np.asarray(svm_mod.svm_score(models["claim"], X, **kw))
    e_sc = np.asarray(svm_mod.svm_score(models["evidence"], X, **kw))
    links = set()
    for i in np.nonzero(c_sc > pcfg.threshold)[0]:
        for j in np.nonzero(e_sc > pcfg.threshold)[0]:
            if keys[i] != keys[j]:
                continue
            s = float(svm_mod.link_score_matrix(
                models["link"], X[i:i + 1], X[j:j + 1])[0, 0])
            if s > 0:
                links.add((int(i), int(j)))
    return links


@pytest.fixture(scope="module")
def corpus():
    docs = synthetic_corpus(3, 40, seed=2)
    X, keys, sents = corpus_arrays(docs, dim=PCFG.feat_dim)
    models, _ = margot_models(PCFG)
    return models, jnp.asarray(X), jnp.asarray(keys)


def test_batch_matches_brute_force(corpus):
    models, X, keys = corpus
    step = make_batch_step(PCFG)
    out = step(models, X, keys)
    assert int(out.n_dropped) == 0, "capacity must cover this corpus"
    got = {(c, e) for c, e, s in extract_links(out)}
    want = brute_force_links(models, np.asarray(X), np.asarray(keys), PCFG)
    assert got == want


def test_permutation_invariance(corpus):
    """Shuffling input rows must not change the link set (modulo row ids)."""
    models, X, keys = corpus
    step = make_batch_step(PCFG)
    perm = np.random.RandomState(0).permutation(X.shape[0])
    out1 = step(models, X, keys)
    out2 = step(models, X[perm], keys[perm])
    links1 = {(int(perm[c]) if False else c, e)
              for c, e, _ in extract_links(out1)}
    # map shuffled indices back to original rows
    links2 = {(int(perm[c]), int(perm[e])) for c, e, _ in extract_links(out2)}
    assert {(c, e) for c, e in links1} == links2


def test_capacity_overflow_counted():
    pcfg = PipelineConfig(feat_dim=64, claim_capacity=2, evid_capacity=2)
    models, _ = margot_models(pcfg)
    docs = synthetic_corpus(2, 50, seed=3)
    X, keys, _ = corpus_arrays(docs, dim=64)
    out = make_batch_step(pcfg)(models, jnp.asarray(X), jnp.asarray(keys))
    assert int(out.n_dropped) > 0      # tiny capacity must overflow and say so


# ----------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
def test_compaction_properties(n, cap, seed):
    """compact_by_score: all kept rows positive, sorted-desc, exact count."""
    rng = np.random.RandomState(seed)
    scores = jnp.asarray(rng.randn(n).astype(np.float32))
    feats = jnp.asarray(rng.randn(n, 4).astype(np.float32))
    keys = jnp.asarray(rng.randint(0, 5, size=n).astype(np.int32))
    out = compact_by_score(feats, scores, keys, cap)
    n_pos = int((np.asarray(scores) > 0).sum())
    kept = int(out.valid.sum())
    assert kept == min(n_pos, cap)
    assert int(out.n_dropped) == max(n_pos - cap, 0)
    s = np.asarray(out.scores)[np.asarray(out.valid)]
    assert np.all(s > 0)
    assert np.all(np.diff(s) <= 1e-6)          # descending
    # kept rows are the TOP-scoring positives
    if kept:
        thresh = np.sort(np.asarray(scores))[::-1][kept - 1]
        assert s.min() >= thresh - 1e-6


# ----------------------------------------------------------------------
SHARDED_CHECK = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.pipeline import PipelineConfig, make_batch_step, extract_links
from repro.data.text import synthetic_corpus, corpus_arrays, margot_models

pcfg = PipelineConfig(feat_dim=256, claim_capacity=16, evid_capacity=32)
models, _ = margot_models(pcfg)
docs = synthetic_corpus(4, 32, seed=5)
X, keys, _ = corpus_arrays(docs, dim=256)
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((8,), ("data",))
step_sharded = make_batch_step(pcfg, mesh=mesh)
out_s = step_sharded(models, jnp.asarray(X), jnp.asarray(keys))
links_s = {(c, e) for c, e, _ in extract_links(out_s)}

# oracle: same per-shard capacities applied shard-locally
n = X.shape[0] // 8
links_r = set()
from repro.core.pipeline import batch_step_local
import repro.core.filtering as F
from repro.models import svm as svm_mod
kw = dict(gamma=pcfg.svm_gamma, coef0=pcfg.svm_coef0, degree=pcfg.svm_degree)
claims_all, evids = [], []
for s in range(8):
    Xs, ks = jnp.asarray(X[s*n:(s+1)*n]), jnp.asarray(keys[s*n:(s+1)*n])
    c_sc = svm_mod.svm_score(models["claim"], Xs, **kw)
    e_sc = svm_mod.svm_score(models["evidence"], Xs, **kw)
    c = F.compact_by_score(Xs, c_sc, ks, pcfg.claim_capacity)
    e = F.compact_by_score(Xs, e_sc, ks, pcfg.evid_capacity)
    claims_all.append((c, s*n))
    evids.append((e, s*n))
for c, coff in claims_all:
    for ci in range(pcfg.claim_capacity):
        if not bool(c.valid[ci]):
            continue
        for e, eoff in evids:
            for ei in range(pcfg.evid_capacity):
                if not bool(e.valid[ei]):
                    continue
                if int(c.keys[ci]) != int(e.keys[ei]):
                    continue
                s_ = float(svm_mod.link_score_matrix(
                    models["link"], c.feats[ci:ci+1], e.feats[ei:ei+1])[0, 0])
                if s_ > 0:
                    links_r.add((int(c.index[ci]) + coff,
                                 int(e.index[ei]) + eoff))
assert links_s == links_r, (sorted(links_s)[:5], sorted(links_r)[:5])
print("SHARDED-OK", len(links_s))
"""


def test_sharded_pipeline_equivalence():
    """shard_map(8 devices) == shard-local oracle, in a subprocess (needs its
    own XLA_FLAGS before jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SHARDED_CHECK], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDED-OK" in r.stdout
