"""Optimizer + partition-size autotuner (mapPartitions analogue)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, settings, st

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)
from repro.core.partitioner import (choose_partition_size, fit_cost_model,
                                    measure_step)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = adamw_init(params)
    target = jnp.array([1.0, 1.0, 1.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, lr=0.05, wd=0.0)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), peak_lr=1.0, warmup=10,
                                 total=100)) for s in range(100)]
    assert lrs[0] < lrs[9]                  # warmup rises
    assert abs(lrs[10] - 1.0) < 0.05        # peak
    assert lrs[-1] < 0.2                    # decays toward min_ratio
    assert min(lrs[10:]) >= 0.1 - 1e-6      # floor


# ----------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.floats(1e-4, 1e-1), st.floats(1e-6, 1e-3))
def test_cost_model_recovers_synthetic(o, c):
    sizes = [1, 2, 4, 8, 16, 32]
    times = [o + c * m for m in sizes]
    model = fit_cost_model(sizes, times)
    assert abs(model.overhead_s - o) / o < 0.05
    assert abs(model.per_item_s - c) / c < 0.05
    assert model.r2 > 0.999


def test_choose_partition_size_tradeoff():
    model = fit_cost_model([1, 16], [0.1 + 1e-3, 0.1 + 16e-3])
    m = choose_partition_size(model, latency_budget_s=1.0,
                              target_efficiency=0.8)
    # needs >= 400 items for 80% efficiency at o=0.1, c=1e-3
    assert model.efficiency(m) >= 0.8
    assert model.time(m) <= 1.0
    # tighter budget forces smaller partitions (the paper's trade-off)
    m_tight = choose_partition_size(model, latency_budget_s=0.2,
                                    target_efficiency=0.8)
    assert m_tight <= m


def test_measure_step_runs():
    import time

    def fake_step(m):
        time.sleep(0.001 + m * 1e-5)

    model = measure_step(fake_step, [1, 8, 32], warmup=0, repeats=1)
    assert model.per_item_s > 0
