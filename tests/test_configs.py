"""Assigned-architecture configs must match the assignment sheet exactly."""
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config

SPEC = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    "whisper-base": (12, 512, 8, 8, 2048, 51865),
    "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
}


@pytest.mark.parametrize("name", list(SPEC))
def test_exact_spec(name):
    cfg = get_config(name)
    L, d, H, KV, ff, V = SPEC[name]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.d_ff == ff
    assert cfg.vocab == V


def test_all_archs_registered():
    assert set(ARCH_IDS) == set(SPEC)
    assert len(all_configs()) == 10


def test_family_details():
    g3 = get_config("gemma3-4b")
    kinds = [k for grp in g3.groups for _ in range(grp.repeats)
             for k in grp.pattern]
    assert len(kinds) == 34
    assert kinds.count("G") == 5 and kinds.count("L") == 29   # 5:1 local:global
    assert g3.window == 1024 and g3.head_dim == 256

    rg = get_config("recurrentgemma-2b")
    kinds = []
    for grp in rg.groups:
        kinds += list(grp.pattern) * grp.repeats
    assert kinds.count("R") == 18 and kinds.count("L") == 8   # 1 attn : 2 lru
    assert rg.lru_width == 2560 and rg.window == 2048

    ds = get_config("deepseek-v2-lite-16b")
    assert ds.kv_lora_rank == 512 and ds.rope_head_dim == 64
    assert ds.n_experts == 64 and ds.top_k == 6 and ds.n_shared_experts == 2
    kinds = [k for grp in ds.groups for _ in range(grp.repeats)
             for k in grp.pattern]
    assert kinds[0] == "D" and kinds.count("M") == 26

    q3 = get_config("qwen3-moe-30b-a3b")
    assert q3.n_experts == 128 and q3.top_k == 8 and q3.qk_norm
    assert q3.n_shared_experts == 0

    fm = get_config("falcon-mamba-7b")
    assert fm.ssm_state == 16 and fm.d_inner == 8192 and fm.dt_rank == 256

    wb = get_config("whisper-base")
    assert wb.enc_layers == 6 and wb.dec_layers == 6
    assert wb.frontend == "audio_frames"

    iv = get_config("internvl2-1b")
    assert iv.frontend == "vision_patches" and iv.n_patches == 256

    g7 = get_config("gemma-7b")
    assert g7.head_dim == 256 and g7.mlp == "geglu"

    sc = get_config("starcoder2-3b")
    assert sc.head_dim == 128 and sc.norm == "layernorm"


def test_param_counts_in_expected_range():
    """Total param counts should be near the named model sizes."""
    from repro.launch.dryrun_lib import model_param_counts
    expected = {
        "starcoder2-3b": (2.5e9, 3.6e9),
        "gemma3-4b": (3.2e9, 5.0e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "whisper-base": (0.04e9, 0.11e9),
        "internvl2-1b": (0.4e9, 1.1e9),
        "recurrentgemma-2b": (2.0e9, 3.2e9),
        "deepseek-v2-lite-16b": (12e9, 18e9),
        "qwen3-moe-30b-a3b": (24e9, 34e9),
        "falcon-mamba-7b": (6.5e9, 8.5e9),
    }
    for name, (lo, hi) in expected.items():
        n = model_param_counts(get_config(name))["total"]
        assert lo <= n <= hi, (name, n)


def test_sub_quadratic_flags():
    from repro.launch.dryrun_lib import LONG_CONTEXT_ARCHS, cell_applicable
    for arch in ARCH_IDS:
        ok, why = cell_applicable(arch, "long_500k")
        assert ok == (arch in LONG_CONTEXT_ARCHS)
        if not ok:
            assert why
