"""MLaaS service front: batching, deadlines, and the launch drivers."""
import subprocess
import sys
import os
import time

import numpy as np
import pytest

from repro.core.partitioner import fit_cost_model
from repro.core.service import MLaaSService


def test_service_batches_and_completes():
    calls = []

    def step(payloads):
        calls.append(len(payloads))
        return [p * 2 for p in payloads]

    svc = MLaaSService(step, capacity=4).start()
    reqs = [svc.submit(i, timeout_s=2.0) for i in range(10)]
    for r in reqs:
        assert r.done.wait(5.0)
    svc.stop()
    assert [r.result for r in reqs] == [2 * i for i in range(10)]
    assert svc.stats["requests"] == 10
    assert max(calls) <= 4


def test_idle_service_does_not_busy_poll():
    """An idle service blocks on its inbox (capped waits) instead of
    spinning at poll_s: over ~0.3s idle it must wake only a handful of
    times (the old 2ms poll woke ~150x), yet a late submit still completes
    promptly and stop() returns without waiting out the cap."""
    svc = MLaaSService(lambda ps: ps, capacity=4).start()
    time.sleep(0.3)
    wakeups_idle = svc.metrics.counter("service.loop_wakeups").value
    assert wakeups_idle <= 25, \
        f"idle loop woke {wakeups_idle}x in 0.3s — still busy-polling"
    r = svc.submit("late", timeout_s=2.0)
    assert r.done.wait(3.0) and r.result == "late"
    t0 = time.monotonic()
    svc.stop()
    assert time.monotonic() - t0 < MLaaSService.IDLE_WAIT_CAP_S + 1.0


def test_service_flushes_on_deadline_slack():
    def slow_step(payloads):
        time.sleep(0.05)
        return payloads

    model = fit_cost_model([1, 4], [0.05, 0.05])   # flat cost
    svc = MLaaSService(slow_step, capacity=64, cost_model=model).start()
    r = svc.submit("only-one", timeout_s=0.5)
    assert r.done.wait(3.0), "lone request must flush before its deadline"
    svc.stop()
    assert not r.missed_deadline
    # capacity 64 never filled: the deadline policy fired
    assert svc.mean_batch() <= 2


def _run(mod, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    return subprocess.run([sys.executable, "-m", mod, *args], env=env,
                          capture_output=True, text=True, timeout=1200)


@pytest.mark.slow
def test_launch_train_driver_resumes(tmp_path):
    d = str(tmp_path / "run")
    r1 = _run("repro.launch.train", "--steps", "6", "--batch", "2",
              "--seq", "32", "--ckpt-every", "3", "--ckpt-dir", d)
    assert r1.returncode == 0, r1.stdout + r1.stderr
    r2 = _run("repro.launch.train", "--steps", "8", "--batch", "2",
              "--seq", "32", "--ckpt-every", "3", "--ckpt-dir", d)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from checkpoint" in r2.stdout


@pytest.mark.slow
def test_launch_serve_driver():
    r = _run("repro.launch.serve", "--requests", "3", "--max-new", "4",
             "--slots", "2", "--max-len", "64")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "tok/s=" in r.stdout
