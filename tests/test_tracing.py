"""Distributed tracing + flight recorder: span trees stay *connected*
across every transport boundary (thread, process pipe, socket — including
reconnect and at-least-once respill), worker spans arrive over the
heartbeat channel, the exporters emit loadable Chrome-trace JSON and
parseable Prometheus text, and a replica death dumps its last flight
events to the artifact store.

Process/socket tests use the echo BackendSpec (no jax in the worker); the
engine-level span tests live with the serve smoke (CI trace-smoke job)
because they pay a compile."""
import json
import re
import time

import numpy as np
import pytest

from repro.cluster import (AdmissionConfig, AdmissionController, FnBackend,
                           MetricsRegistry, ReplicaConfig, Router, Status,
                           TraceContext, Tracer, current_recorder,
                           current_tracer, echo_spec, prometheus_text,
                           set_recorder, set_tracer, to_chrome_trace)
from repro.cluster.tracing import NULL_SPAN, FlightRecorder
from repro.cluster.transport import default_flight_store

PROC_CFG = ReplicaConfig(inbox_capacity=256, max_batch=4)


@pytest.fixture
def tracer():
    """Fresh full-sampling tracer + flight recorder installed as the
    process globals, restored afterwards (both are module-level state)."""
    prev_t, prev_r = current_tracer(), current_recorder()
    tr = Tracer(enabled=True, sample_rate=1.0, capacity=8192,
                replica="parent")
    set_tracer(tr)
    set_recorder(FlightRecorder(replica="parent"))
    yield tr
    set_tracer(prev_t)
    set_recorder(prev_r)


def _trees(spans):
    """Group spans by trace id and verify connectivity: every parent
    pointer resolves inside the same trace and each trace has exactly one
    root.  Returns {trace_id: [span, ...]}."""
    by_trace = {}
    for s in spans:
        by_trace.setdefault(s["trace"], []).append(s)
    for tid, group in by_trace.items():
        ids = {s["span"] for s in group}
        roots = [s for s in group if not s["parent"]]
        assert len(roots) == 1, \
            f"trace {tid}: {len(roots)} roots in {[s['name'] for s in group]}"
        for s in group:
            if s["parent"]:
                assert s["parent"] in ids, \
                    f"trace {tid}: span {s['name']} orphaned"
    return by_trace


def _poll_spans(tr, pred, timeout_s=10.0):
    """Heartbeat shipping is asynchronous: poll until the predicate holds
    over the tracer's buffer (or time out and let the assert show why)."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        spans = tr.spans()
        if pred(spans):
            return spans
        time.sleep(0.05)
    return tr.spans()


# ----------------------------------------------------------------------
def test_span_tree_ids_tags_and_context(tracer):
    with tracer.span("request", rid=7) as root:
        ctx = root.context()
        assert ctx.trace_id == root.trace_id and ctx.sampled
        with tracer.span("child", parent=ctx, bucket=16) as child:
            child.tag(n=3)
        assert child.parent_id == root.span_id
    spans = tracer.spans()
    assert [s["name"] for s in spans] == ["child", "request"]  # end order
    child_s, root_s = spans
    assert child_s["tags"] == {"bucket": 16, "n": 3}
    assert root_s["tags"] == {"rid": 7}
    assert child_s["t1"] >= child_s["t0"] and root_s["t1"] >= root_s["t0"]
    assert root_s["replica"] == "parent"
    _trees(spans)
    # double-end is inert, tags coerce to scalars
    root.end()
    assert len(tracer.spans()) == 2
    with tracer.span("odd", arr=np.arange(3), obj=object()) as sp:
        pass
    tags = tracer.spans()[-1]["tags"]
    assert isinstance(tags["arr"], str) and isinstance(tags["obj"], str)


def test_sampling_follower_mode_and_bounded_buffer():
    # disabled tracer: pure no-op singletons, no allocation per call
    off = Tracer(enabled=False)
    assert off.span("x") is NULL_SPAN and off.span("x").ctx is None
    # rate 0 never roots…
    follower = Tracer(enabled=True, sample_rate=0.0, replica="w1")
    assert follower.span("root") is NULL_SPAN
    # …but always records children of a sampled incoming context — this
    # is how workers follow the parent's single sampling decision
    ctx = TraceContext("t1", "s1", sampled=True)
    sp = follower.span("replica.batch", parent=ctx)
    assert sp.recording
    sp.end()
    assert follower.spans()[0]["parent"] == "s1"
    # an *unsampled* context records nothing anywhere
    assert follower.span("x", parent=TraceContext("t", "s", False)) \
        is NULL_SPAN
    # bounded buffer: overflow drops oldest and counts drops
    tiny = Tracer(enabled=True, sample_rate=1.0, capacity=4)
    for i in range(10):
        tiny.span(f"s{i}").end()
    assert len(tiny.spans()) == 4 and tiny.dropped == 6
    assert tiny.spans()[0]["name"] == "s6"
    # rate sampling: deterministic bounds over many roots
    half = Tracer(enabled=True, sample_rate=0.5)
    kept = sum(half.span("r").recording for _ in range(2000))
    assert 700 < kept < 1300


def test_trace_context_wire_roundtrip():
    ctx = TraceContext("abc-1", "abc-2", sampled=True, attempt=3)
    wire = ctx.to_wire()
    back = TraceContext.from_wire(wire)
    assert (back.trace_id, back.span_id, back.sampled, back.attempt) == \
        ("abc-1", "abc-2", True, 3)
    # wire format survives msgpack-style list/tuple coercion
    assert TraceContext.from_wire(list(wire)).span_id == "abc-2"
    # malformed contexts drop to None instead of raising mid-frame
    for bad in (None, [], ["only-one"], "nope", 7):
        assert TraceContext.from_wire(bad) is None


# ----------------------------------------------------------------------
def test_thread_transport_single_connected_tree(tracer):
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m,
               admission=AdmissionController(
                   AdmissionConfig(max_queue_cost=4096), m))
    for _ in range(2):
        r.add_replica(FnBackend(lambda ps: [p * 2 for p in ps]),
                      ReplicaConfig(max_batch=4))
    reqs = [r.submit(i, cost=1) for i in range(8)]
    assert [r.wait(q, 15.0) for q in reqs] == [2 * i for i in range(8)]
    r.stop()
    spans = tracer.spans()
    trees = _trees(spans)
    assert len(trees) == 8                       # one trace per request
    for group in trees.values():
        names = {s["name"] for s in group}
        assert {"request", "admission.decide", "router.dispatch",
                "transport.inflight"} <= names
        root = next(s for s in group if not s["parent"])
        assert root["name"] == "request"
        inflight = next(s for s in group
                        if s["name"] == "transport.inflight")
        assert inflight["t1"] >= inflight["t0"]
        assert not inflight["tags"].get("spilled")
    # a batch span parents to its first member's trace — every one must
    # land inside SOME request tree (connected, checked by _trees above),
    # and at least one exists
    assert any(s["name"] == "replica.batch" for s in spans)


def test_process_worker_spans_arrive_via_heartbeat(tracer):
    r = Router(policy="round_robin", metrics=MetricsRegistry())
    for _ in range(2):
        r.add_replica(spec=echo_spec(delay_s=0.001), cfg=PROC_CFG,
                      transport="process")
    reqs = [r.submit(i) for i in range(12)]
    assert [r.wait(q, 30.0) for q in reqs] == [2 * i for i in range(12)]
    # worker-side replica.batch spans ship over the heartbeat channel
    spans = _poll_spans(
        tracer, lambda ss: sum(s["name"] == "replica.batch"
                               for s in ss) >= 1)
    rids = {str(w.rid) for w in r.alive_replicas()}
    r.stop()
    batch = [s for s in spans if s["name"] == "replica.batch"]
    assert batch, "no worker spans arrived over heartbeats"
    # shipped spans are re-homed to the worker's replica id, and their
    # parent pointers land inside the parent-side trees: still connected
    assert all(s["replica"] in rids for s in batch), \
        [(s["replica"], rids) for s in batch]
    trees = _trees(spans)
    crossed = [t for t, g in trees.items()
               if {"request", "replica.batch"} <=
               {s["name"] for s in g}]
    assert crossed, "no trace crossed the process boundary intact"


def test_respill_keeps_attempts_as_tagged_siblings(tracer):
    """Soft-crash one of two process replicas mid-load: every request
    completes (at-least-once), the dead attempt's transport span survives
    tagged ``spilled`` — and the retry dispatch creates NEW spans tagged
    with the attempt number instead of merging into the dead ones."""
    r = Router(policy="round_robin", metrics=MetricsRegistry(),
               max_retries=3)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.01), cfg=PROC_CFG,
                             transport="process")
               for _ in range(2)]
    reqs = [r.submit(i) for i in range(30)]
    time.sleep(0.02)
    workers[0].inject_crash(soft=True)
    assert [r.wait(q, 30.0) for q in reqs] == [2 * i for i in range(30)]
    spans = _poll_spans(
        tracer, lambda ss: any(s["tags"].get("spilled") for s in ss))
    r.stop()
    trees = _trees(spans)                        # still connected
    spilled = [s for s in spans if s["tags"].get("spilled")]
    assert spilled, "dead attempt left no spilled-tagged span"
    retried = [s for s in spans if s["name"] == "transport.inflight"
               and s["tags"].get("attempt")]
    assert retried, "respill dispatched no attempt-tagged span"
    # dead attempt and retry are sibling spans, not one mutated record
    assert {s["span"] for s in retried}.isdisjoint(
        {s["span"] for s in spilled})
    assert len(trees) >= 30
    # the spill leaves an audit trail in the flight recorder too
    kinds = {e["kind"] for e in current_recorder().events()}
    assert "spill" in kinds and "replica_death" in kinds


def test_socket_sever_reconnect_trace_and_recorder(tracer):
    r = Router(policy="round_robin", metrics=MetricsRegistry(),
               max_retries=5)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.01), cfg=PROC_CFG,
                             transport="socket")
               for _ in range(2)]
    w = workers[0]
    assert all(x.wait_ready(30.0) for x in workers)
    pre = [r.submit(i) for i in range(8)]
    time.sleep(0.03)
    w.sever_connection()                  # partition: worker redials
    post = [r.submit(100 + i) for i in range(8)]
    for q in pre + post:
        assert q.done.wait(30.0)
    assert all(q.status is Status.OK for q in pre + post)
    # spans recorded by the worker *after* the reconnect still connect to
    # parent-side trees (the context rode the respill/new offer frames)
    spans = _poll_spans(
        tracer, lambda ss: sum(s["name"] == "replica.batch"
                               for s in ss) >= 2)
    _trees(spans)
    kinds = {e["kind"] for e in current_recorder().events()}
    assert "partition" in kinds           # sever_connection audit event
    assert "disconnect" in kinds or "reconnect" in kinds
    r.stop()


# ----------------------------------------------------------------------
def test_chrome_trace_export_schema(tracer):
    with tracer.span("request", rid=1) as root:
        tracer.span("engine.prefill", parent=root, bucket=16).end()
    follower = Tracer(enabled=True, sample_rate=0.0, replica="1")
    follower.span("replica.batch", parent=root.ctx).end()
    tracer.ingest(follower.drain(), replica="1")
    root.end()
    doc = to_chrome_trace(tracer.spans())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 3
    for e in xs:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
        assert e["dur"] >= 0 and e["ts"] > 0
        assert e["args"]["trace_id"] and e["args"]["span_id"]
    # one pid per replica, named via metadata events
    metas = [e for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"]
    assert {m["args"]["name"] for m in metas} == \
        {"replica:parent", "replica:1"}
    assert len({e["pid"] for e in xs}) == 2
    json.loads(json.dumps(doc))           # round-trips as plain JSON


def test_prometheus_text_exposition_parses():
    reg = MetricsRegistry()
    reg.counter("router.completed").inc(5)
    reg.gauge("engine.kv_blocks_free").set(37)
    for v in (0.01, 0.02, 0.02, 0.5, 3.0):
        reg.histogram("replica.batch_s").observe(v)
    text = prometheus_text(reg.snapshot())
    lines = text.strip().splitlines()
    assert any(line.startswith("# TYPE") for line in lines)
    sample_re = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [0-9eE+.inf-]+$')
    for line in lines:
        if line.startswith("#"):
            continue
        assert sample_re.match(line), f"unparseable sample line: {line}"
    assert "repro_router_completed 5" in text
    # histogram: cumulative buckets, +Inf equals count, sum consistent
    buckets = [(float(m.group(1).replace("+Inf", "inf")),
                float(m.group(2)))
               for m in re.finditer(
                   r'repro_replica_batch_s_bucket\{le="([^"]+)"\} (\S+)',
                   text)]
    assert buckets and buckets[-1][0] == float("inf")
    les = [b[0] for b in buckets]
    cums = [b[1] for b in buckets]
    assert les == sorted(les) and cums == sorted(cums)
    assert cums[-1] == 5.0
    count = float(re.search(
        r"repro_replica_batch_s_count (\S+)", text).group(1))
    total = float(re.search(
        r"repro_replica_batch_s_sum (\S+)", text).group(1))
    assert count == 5.0 and total == pytest.approx(3.55, rel=1e-6)


def test_prometheus_every_metric_has_help_before_type():
    reg = MetricsRegistry()
    reg.counter("router.completed").inc()
    reg.gauge("router.queue_depth").set(3)
    reg.histogram("replica.batch_s").observe(0.1)
    lines = prometheus_text(reg.snapshot()).strip().splitlines()
    helped = set()
    for line in lines:
        if line.startswith("# HELP "):
            name = line.split()[2]
            assert line.split(None, 3)[3:], f"empty HELP text: {line}"
            helped.add(name)
        elif line.startswith("# TYPE "):
            assert line.split()[2] in helped, \
                f"# TYPE without preceding # HELP: {line}"


def test_prometheus_name_collision_gets_dup_suffix():
    """Two source keys sanitizing to the same metric name must not
    interleave into one series — the later sorted key is renamed."""
    text = prometheus_text({"a.b": 1.0, "a_b": 2.0})
    assert "repro_a_b 1" in text
    assert "repro_a_b_dup2 2" in text
    names = [ln.split()[0] for ln in text.splitlines()
             if ln and not ln.startswith("#")]
    assert len(names) == len(set(names)), names


def test_prometheus_repairs_torn_merge_histogram():
    """A torn cluster merge can ship a negative per-bucket delta and a
    ``.count`` below the bucket total; the exporter must still emit
    monotone cumulative buckets with ``+Inf`` == ``_count``."""
    snap = {
        "lat_s.count": 3.0,        # below the bucket total of 5
        "lat_s.mean": 0.2,
        "lat_s.p50": 0.1,
        "lat_s.le4": 4.0,
        "lat_s.le5": -2.0,         # torn: clamps to zero, never dips
        "lat_s.le6": 1.0,
    }
    text = prometheus_text(snap)
    cums = [float(m.group(2)) for m in re.finditer(
        r'repro_lat_s_bucket\{le="([^"]+)"\} (\S+)', text)]
    assert cums == sorted(cums), cums
    count = float(re.search(r"repro_lat_s_count (\S+)", text).group(1))
    assert cums[-1] == count == 5.0
    # legacy bucket-less stem: +Inf is synthesized equal to the count
    legacy = prometheus_text({"old_s.count": 7.0, "old_s.p50": 0.5,
                              "old_s.mean": 0.5})
    inf = float(re.search(r'repro_old_s_bucket\{le="\+Inf"\} (\S+)',
                          legacy).group(1))
    lcount = float(re.search(r"repro_old_s_count (\S+)",
                             legacy).group(1))
    assert inf == lcount == 7.0


# ----------------------------------------------------------------------
def test_replica_kill_dumps_flight_events_to_artifact_store(tracer):
    """Killing a worker mid-batch must leave a crash dump in the artifact
    store holding the batch's audit trail: the submit and the spill (with
    the lost rids), plus whatever the worker shipped before dying."""
    r = Router(policy="round_robin", metrics=MetricsRegistry(),
               max_retries=3)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.01), cfg=PROC_CFG,
                             transport="process")
               for _ in range(2)]
    reqs = [r.submit(i) for i in range(20)]
    time.sleep(0.03)
    workers[0].inject_crash()             # SIGKILL mid-batch
    for q in reqs:
        assert q.done.wait(30.0)
    assert all(q.status is Status.OK for q in reqs)
    assert workers[0].flight_dumps, "replica death produced no dump"
    doc = json.loads(default_flight_store().read_bytes(
        workers[0].flight_dumps[-1]))
    assert doc["rid"] == workers[0].rid
    kinds = [e["kind"] for e in doc["parent_events"]]
    assert "submit" in kinds and "replica_death" in kinds
    spill = next(e for e in doc["parent_events"] if e["kind"] == "spill")
    assert spill["rids"], "dump must name the spilled batch's requests"
    spilled_rids = set(spill["rids"])
    assert spilled_rids <= {q.rid for q in reqs}
    r.stop()


def test_tracing_disabled_leaves_no_spans_and_no_wire_context():
    """The default (null) tracer end to end: no spans accumulate and the
    wire frames carry no context — the observability layer must vanish
    when off."""
    assert current_tracer().span("request") is NULL_SPAN
    r = Router(policy="round_robin", metrics=MetricsRegistry())
    r.add_replica(spec=echo_spec(delay_s=0.001), cfg=PROC_CFG,
                  transport="process")
    reqs = [r.submit(i) for i in range(6)]
    assert [r.wait(q, 30.0) for q in reqs] == [2 * i for i in range(6)]
    assert all(q.trace_span is None and q.trace_ctx is None for q in reqs)
    r.stop()
    assert current_tracer().spans() == []
