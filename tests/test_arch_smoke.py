"""Per-architecture smoke tests: REDUCED configs of the same family, one
forward + one train step on CPU, asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import api

BATCH, SEQ = 2, 32


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = reduced(get_config(request.param))
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_forward_shapes(arch):
    cfg, params = arch
    batch = api.input_batch(cfg, "train", BATCH, SEQ)
    logits = api.forward_fn(params, cfg, batch)
    assert logits.shape[0] == BATCH
    assert logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"


def test_train_step(arch):
    cfg, params = arch
    batch = api.input_batch(cfg, "train", BATCH, SEQ)

    def loss(p):
        return api.loss_fn(p, cfg, batch)[0]

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert bool(jnp.isfinite(val)), f"loss not finite: {val}"
    flat = jax.tree_util.tree_leaves(grads)
    assert flat, "no grads"
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), "non-finite grad"


def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill must match teacher-forced forward logits."""
    cfg, params = arch
    if cfg.family == "encdec":
        pytest.skip("covered in test_encdec_decode")
    batch = api.input_batch(cfg, "train", BATCH, SEQ)
    tokens = batch["tokens"]
    full = api.forward_fn(params, cfg, batch)          # (B, S_total, V)

    caches = api.init_caches(cfg, BATCH, SEQ + 8)
    logits_p, caches = api.prefill_fn(params, cfg, batch, caches)
    # teacher-forced last-position logits == prefill logits
    ref_last = full[:, -1:, :]
    assert jnp.allclose(logits_p.astype(jnp.float32),
                        ref_last.astype(jnp.float32), atol=2e-2, rtol=2e-2), (
        float(jnp.max(jnp.abs(logits_p - ref_last))))

    # one decode step: feed argmax token; shapes must hold & logits finite
    ntok = jnp.argmax(logits_p[:, -1], axis=-1).astype(jnp.int32)[:, None]
    S_ctx = tokens.shape[1] + (batch["patches"].shape[1] if "patches" in batch else 0)
    step = {"tokens": ntok, "pos": jnp.full((BATCH,), S_ctx, jnp.int32)}
    logits_d, caches = api.decode_fn(params, cfg, step, caches)
    assert logits_d.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits_d)))


@pytest.mark.parametrize("name", [
    "internlm2-1.8b",        # dense GQA, full cache
    "gemma3-4b",             # local ring cache + dual rope + tied emb
    "recurrentgemma-2b",     # RG-LRU state + MQA ring cache
    "falcon-mamba-7b",       # SSM conv+h state
    "deepseek-v2-lite-16b",  # MLA absorbed decode + MoE
    "qwen3-moe-30b-a3b",     # MoE + qk-norm
])
def test_decode_matches_forward_stepwise(name):
    """Strong equivalence: decoding token-by-token from an empty cache
    reproduces the teacher-forced logits at every position (T > window so
    ring caches actually wrap)."""
    from repro.models import transformer as tfm
    cfg = reduced(get_config(name))
    if cfg.n_experts:
        # full-seq routing drops tokens at finite capacity while per-token
        # decode never does; equivalence holds at no-drop capacity.
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    params, _ = api.init(jax.random.PRNGKey(1), cfg)
    T = 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, T), 0, cfg.vocab)
    full, _ = tfm.forward(params, cfg, tokens=tokens)

    caches = api.init_caches(cfg, 1, T + 1)
    outs = []
    for t in range(T):
        lg, caches = tfm.decode_step(params, cfg, tokens[:, t:t + 1], caches,
                                     jnp.array([t], jnp.int32))
        outs.append(lg[:, 0])
    stepwise = jnp.stack(outs, axis=1)
    assert jnp.allclose(stepwise.astype(jnp.float32), full.astype(jnp.float32),
                        atol=5e-2, rtol=5e-2), (name, float(
        jnp.max(jnp.abs(stepwise - full))))


def test_encdec_decode():
    cfg = reduced(get_config("whisper-base"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    from repro.models import encdec
    B, S = 2, 16
    frames = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    enc = encdec.encode(params, frames, cfg)
    full = encdec.decode_full(params, tokens, enc, cfg)

    caches = encdec.init_caches(cfg, B, S + 4, S)
    logits_p, caches = encdec.prefill(params, tokens, frames, cfg, caches)
    assert jnp.allclose(logits_p.astype(jnp.float32),
                        full[:, -1:].astype(jnp.float32), atol=2e-2, rtol=2e-2)
    nxt = jnp.argmax(logits_p[:, -1], -1).astype(jnp.int32)[:, None]
    lg, _ = encdec.decode_step(params, nxt, caches, jnp.full((B,), S, jnp.int32), cfg)
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
