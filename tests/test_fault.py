"""Fault tolerance: speculation, retry, replay, checkpoint, elastic re-mesh."""
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fault import ReplayLog, speculative_map
from repro.checkpoint import Checkpointer


def test_speculative_map_results_in_order():
    out, stats = speculative_map(lambda x: x * x, list(range(20)), n_workers=4)
    assert out == [x * x for x in range(20)]
    assert stats.launched >= 20


def test_speculative_map_mitigates_straggler():
    calls = {}

    def fn(i):
        first = i not in calls
        calls[i] = calls.get(i, 0) + 1
        if i == 3 and first:
            time.sleep(1.0)            # straggling first attempt
            return -1                  # late result should be discarded
        time.sleep(0.01)
        return i

    out, stats = speculative_map(fn, list(range(8)), n_workers=4,
                                 straggler_factor=3.0, min_median_s=0.02)
    assert out[3] == 3                 # speculative copy won
    assert stats.speculated >= 1


def test_speculative_map_retries_failures():
    attempts = {}

    def fn(i):
        attempts[i] = attempts.get(i, 0) + 1
        if i == 2 and attempts[i] == 1:
            raise RuntimeError("node died")
        return i * 10

    out, stats = speculative_map(fn, list(range(6)), n_workers=3)
    assert out == [i * 10 for i in range(6)]
    assert stats.retried_failures == 1


def test_speculative_map_exhausted_retries_raises():
    def fn(i):
        if i == 1:
            raise RuntimeError("always dies")
        return i

    with pytest.raises(RuntimeError):
        speculative_map(fn, list(range(3)), n_workers=2, max_retries=1)


# ----------------------------------------------------------------------
def test_replay_log_resume(tmp_path):
    log = ReplayLog(str(tmp_path / "replay.jsonl"))
    for mb in range(10):
        log.record(mb, offset=mb * 64, seed=42)
    # crash after mb 9; checkpoint was at mb 6
    resume = log.resume_point(checkpoint_mb=6)
    assert resume["mb_id"] == 7 and resume["offset"] == 7 * 64


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4), {"c": jnp.zeros(())}]}
    for step in (1, 2, 3):
        ck.save(step, jax.tree_util.tree_map(lambda x: x + step, tree))
    assert ck.latest_step() == 3
    assert ck.steps() == [2, 3]                     # gc kept last 2
    got = ck.restore(tree, step=3)
    want = jax.tree_util.tree_map(lambda x: x + 3, tree)
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(5, {"x": jnp.ones((128, 128))})
    ck.wait()
    assert ck.latest_step() == 5
    got = ck.restore({"x": jnp.zeros((128, 128))})
    assert float(got["x"].sum()) == 128 * 128


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.ones(3)})
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)


# ----------------------------------------------------------------------
ELASTIC_CHECK = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.fault import ElasticRunner
from repro.core.pipeline import PipelineConfig, make_batch_step, extract_links
from repro.data.text import synthetic_corpus, corpus_arrays, margot_models

pcfg = PipelineConfig(feat_dim=128, claim_capacity=16, evid_capacity=16)
models, axes = margot_models(pcfg)
docs = synthetic_corpus(2, 32, seed=6)
X, keys, _ = corpus_arrays(docs, dim=128)
devs = np.array(jax.devices())

mesh8 = Mesh(devs.reshape(8), ("data",))
mesh4 = Mesh(devs[:4].reshape(4), ("data",))

runner = ElasticRunner(models, axes, mesh8, policy="broadcast")
step8 = make_batch_step(pcfg, mesh=mesh8)
out8 = step8(runner.params, jnp.asarray(X), jnp.asarray(keys))
links8 = {(c, e) for c, e, _ in extract_links(out8)}

# node failure: rescale to 4 devices (elastic shrink), same results expected
runner.rescale(mesh4)
step4 = make_batch_step(pcfg, mesh=mesh4)
out4 = step4(runner.params, jnp.asarray(X), jnp.asarray(keys))
links4 = {(c, e) for c, e, _ in extract_links(out4)}

# different shard counts change per-shard capacities, so compare against the
# oracle invariant instead: every link found on 4 shards whose rows were kept
# on 8 shards must match.  For this corpus capacities are not saturated, so
# the link sets are identical.
assert links8 == links4, (len(links8), len(links4))
print("ELASTIC-OK", runner.generation, len(links8))
"""


def test_elastic_rescale_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", ELASTIC_CHECK], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ELASTIC-OK 1" in r.stdout


def test_stream_checkpoint_restart_resumes_state(tmp_path):
    """Kill-and-restart: restored stream state continues identically."""
    from repro.core.pipeline import PipelineConfig
    from repro.core.stream import StreamConfig, StreamRuntime
    from repro.data.text import corpus_arrays, margot_models, synthetic_corpus

    pcfg = PipelineConfig(feat_dim=64, claim_capacity=16, evid_capacity=16)
    scfg = StreamConfig(period=5.0, capacity=16, scope="window", window=20.0,
                        ring_capacity=64)
    models, _ = margot_models(pcfg)
    docs = synthetic_corpus(2, 48, seed=7)
    X, keys, _ = corpus_arrays(docs, dim=64)
    ts = np.arange(len(keys), dtype=np.float32)

    ck = Checkpointer(str(tmp_path))
    rt = StreamRuntime(models, pcfg, scfg, checkpointer=ck, checkpoint_every=3)
    outs = []
    for start in range(0, 64, 16):
        outs.append(rt.process_microbatch(X[start:start + 16],
                                          keys[start:start + 16],
                                          ts[start:start + 16]))
    # crash after mb 4; last checkpoint at mb 3 -> replay mb 4 only
    rt2 = StreamRuntime(models, pcfg, scfg)
    rt2.state = ck.restore({"state": rt2.state})["state"]
    assert int(rt2.state.microbatch_id) == 3
    replay = []
    for start in (48,):
        replay.append(rt2.process_microbatch(X[start:start + 16],
                                             keys[start:start + 16],
                                             ts[start:start + 16]))
    for (s1, m1), (s2, m2) in zip(outs[3:], replay):
        np.testing.assert_allclose(s1, s2, atol=1e-5)
        np.testing.assert_array_equal(m1, m2)
