"""Fused K-step engine: token-for-token parity with the per-token reference
path, bucketed batch prefill, max_new/truncation semantics.

The fused path (donated caches, in-jit sampling, ``lax.fori_loop`` over K
decode steps, bucketed batch prefill) must be an *observationally invisible*
optimization: for every decoder family it emits exactly the tokens the
pre-PR per-token loop emits, including when slots complete mid-K-loop and
are refilled from the queue.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api
from repro.serving import Engine, ServeConfig, pad_tolerant

FAMILIES = ["internlm2-1.8b",       # transformer (full attention)
            "falcon-mamba-7b",      # SSM (Mamba-1)
            "recurrentgemma-2b"]    # RG-LRU hybrid (Griffin)


def _model(arch, seed=0):
    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _drain(params, cfg, scfg, prompts, max_new):
    eng = Engine(params, cfg, scfg)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run_until_drained()
    return eng, reqs


@pytest.mark.parametrize("arch", FAMILIES)
def test_fused_matches_reference_with_refill(arch):
    """5 requests through 2 slots: slots complete mid-K-loop and refill from
    the queue; K does not divide max_new.  Token streams must be identical
    request-for-request."""
    cfg, params = _model(arch)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 7, 12, 6)]
    _, ref = _drain(params, cfg,
                    ServeConfig(max_len=64, slots=2, fused=False),
                    prompts, max_new=6)
    _, fus = _drain(params, cfg,
                    ServeConfig(max_len=64, slots=2, fused=True,
                                sync_every=4),
                    prompts, max_new=6)
    for i, (a, b) in enumerate(zip(ref, fus)):
        assert a.out_tokens == b.out_tokens, (arch, i)
        assert a.finish_reason == b.finish_reason == "max_new"


def test_max_new_means_decoded_tokens():
    """The prefill-sampled token is free: ``max_new`` counts decode-step
    tokens only, and ``engine.tokens`` counts the same."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab, size=6).astype(np.int32)
               for _ in range(3)]
    for fused in (False, True):
        eng, reqs = _drain(params, cfg,
                           ServeConfig(max_len=64, slots=2, fused=fused),
                           prompts, max_new=5)
        for r in reqs:
            assert len(r.out_tokens) == 6          # 1 prefill + 5 decoded
            assert r.decoded == 5
            assert r.finish_reason == "max_new"
        assert eng.metrics.counter("engine.tokens").value == 15, fused


@pytest.mark.parametrize("fused", [False, True])
def test_truncation_records_reason(fused):
    """A slot that hits ``max_len - 1`` before exhausting its budget stops
    with an explicit ``max_len`` finish reason (and the truncation
    counter), not a silent short completion."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab, size=8).astype(np.int32)
    scfg = ServeConfig(max_len=16, slots=2, fused=fused, sync_every=4)
    eng, (req,) = _drain(params, cfg, scfg, [prompt], max_new=100)
    assert req.done and req.finish_reason == "max_len"
    # prefill wrote positions 0..7; decode writes 8..14 (max_len-2) -> 7
    # decoded tokens, pos parked at max_len-1
    assert req.decoded == scfg.max_len - 1 - len(prompt)
    assert eng.metrics.counter("engine.truncated").value == 1


def test_truncation_parity_mid_loop():
    """Truncation must fire at the same token index on both paths even when
    it lands mid-K-loop."""
    cfg, params = _model("falcon-mamba-7b")
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 9)]
    _, ref = _drain(params, cfg,
                    ServeConfig(max_len=16, slots=2, fused=False),
                    prompts, max_new=100)
    _, fus = _drain(params, cfg,
                    ServeConfig(max_len=16, slots=2, fused=True,
                                sync_every=8),
                    prompts, max_new=100)
    for a, b in zip(ref, fus):
        assert a.out_tokens == b.out_tokens
        assert a.finish_reason == b.finish_reason == "max_len"


def test_fused_matches_reference_moe():
    """MoE rows couple through expert capacity, so admits are batch-1 and
    inactive slots feed token 0 like the reference loop.  Without a
    mid-K-loop refill (requests <= slots) the streams must be identical;
    with refills, sync_every=1 restores step-for-step batch composition
    and therefore exactness."""
    cfg, params = _model("qwen3-moe-30b-a3b")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 8)]
    _, ref = _drain(params, cfg,
                    ServeConfig(max_len=64, slots=2, fused=False),
                    prompts, max_new=6)
    _, fus = _drain(params, cfg,
                    ServeConfig(max_len=64, slots=2, fused=True,
                                sync_every=4),
                    prompts, max_new=6)
    for a, b in zip(ref, fus):
        assert a.out_tokens == b.out_tokens
    # refill case at K=1: admit timing matches the reference step-for-step
    more = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
            for n in (5, 8, 6, 5)]
    _, ref2 = _drain(params, cfg,
                     ServeConfig(max_len=64, slots=2, fused=False),
                     more, max_new=5)
    _, fus2 = _drain(params, cfg,
                     ServeConfig(max_len=64, slots=2, fused=True,
                                 sync_every=1),
                     more, max_new=5)
    for a, b in zip(ref2, fus2):
        assert a.out_tokens == b.out_tokens


def test_pad_tolerance_gate():
    """Which families may take the padded-bucket prefill path: plain causal
    attention yes; SSM / RG-LRU (recurrent state), MoE (capacity coupling),
    and ring-cache windowed attention no."""
    assert pad_tolerant(reduced(get_config("internlm2-1.8b")), 64)
    assert not pad_tolerant(reduced(get_config("falcon-mamba-7b")), 64)
    assert not pad_tolerant(reduced(get_config("recurrentgemma-2b")), 64)
    assert not pad_tolerant(reduced(get_config("deepseek-v2-lite-16b")), 64)
    assert not pad_tolerant(reduced(get_config("gemma3-4b")), 64)


def test_bucketed_prefill_batches_admits():
    """Pad-tolerant arch, mixed prompt lengths in one power-of-two bucket:
    the engine admits them in a single batched prefill call and the padded
    prefill is exact (tokens match the exact-length reference path)."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(4)
    # lengths 5..8 share the size-8 bucket
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 8, 6, 7)]
    fus_eng, fus = _drain(params, cfg,
                          ServeConfig(max_len=64, slots=4, fused=True,
                                      sync_every=4),
                          prompts, max_new=6)
    assert fus_eng.metrics.counter("engine.prefill_batches").value == 1
    _, ref = _drain(params, cfg,
                    ServeConfig(max_len=64, slots=4, fused=False),
                    prompts, max_new=6)
    for a, b in zip(ref, fus):
        assert a.out_tokens == b.out_tokens


def test_exact_length_path_still_batches_same_length():
    """Pad-intolerant family (SSM): same-length prompts still share one
    exact-length batched prefill (no pads introduced)."""
    cfg, params = _model("falcon-mamba-7b")
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab, size=7).astype(np.int32)
               for _ in range(3)]
    eng, reqs = _drain(params, cfg,
                       ServeConfig(max_len=64, slots=4, fused=True),
                       prompts, max_new=4)
    assert eng.metrics.counter("engine.prefill_batches").value == 1
    assert all(r.done for r in reqs)


def test_temperature_sampling_in_jit():
    """temperature > 0 samples on device: tokens are valid ids and two
    engines with different seeds diverge (smoke, not a parity claim)."""
    cfg, params = _model("internlm2-1.8b")
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, cfg.vocab, size=6).astype(np.int32)]
    outs = []
    for seed in (0, 1):
        _, (req,) = _drain(params, cfg,
                           ServeConfig(max_len=64, slots=2, fused=True,
                                       temperature=1.0, seed=seed),
                           [p.copy() for p in prompts], max_new=12)
        assert all(0 <= t < cfg.padded_vocab for t in req.out_tokens)
        outs.append(req.out_tokens)
    assert outs[0] != outs[1], "different rng seeds should diverge"
