"""Socket transport: the multi-host worker path.

Covers the versioned (re)connect handshake (bad protocol version, unknown
token, spec-fingerprint mismatch all rejected at the door), reconnect
resuming session placement, connection-drop and SIGKILL mid-batch spilling
with zero lost requests, heartbeat-timeout crash detection (an *open but
silent* connection is dead — no process liveness involved), and the
content-addressed artifact store a remote worker fetches weights through.

Real workers here are echo/scaler BackendSpecs (no jax import), spawned
locally and dialing back over loopback TCP — the identical code path a
worker on another host runs via ``python -m repro.cluster.worker_main``.
Handshake edge cases use raw in-test channels instead of spawned workers,
so they are fast and deterministic.
"""
import os
import time

import numpy as np
import pytest

from repro.cluster import (ArtifactStore, BackendSpec, MetricsRegistry,
                           ReplicaConfig, Router, Status, artifact_ref,
                           echo_spec, make_transport, resolve_spec,
                           spec_fingerprint)
from repro.cluster.replica import FnBackend
from repro.cluster.transport import SocketTransport
from repro.cluster.wire import (PROTOCOL_VERSION, ChannelClosed,
                                WorkerListener, connect_channel)

CFG = ReplicaConfig(inbox_capacity=256, max_batch=4, heartbeat_timeout_s=2.0)


def _wait_until(pred, timeout_s=10.0, period=0.02):
    t_end = time.monotonic() + timeout_s
    while time.monotonic() < t_end:
        if pred():
            return True
        time.sleep(period)
    return pred()


def _recv_frame(chan, timeout_s=5.0):
    t_end = time.monotonic() + timeout_s
    while time.monotonic() < t_end:
        msg = chan.recv(0.1)
        if msg is not None:
            return msg
    return None


# ----------------------------------------------------------------------
# Handshake.

def test_wrong_protocol_version_rejected():
    listener = WorkerListener()
    try:
        chan = connect_channel(listener.address)
        chan.send(("hello", PROTOCOL_VERSION + 1, "any-token", None, None))
        msg = _recv_frame(chan)
        assert msg is not None and msg[0] == "reject"
        assert "version" in msg[1]
        # the listener hangs up after rejecting
        with pytest.raises(ChannelClosed):
            for _ in range(100):
                if chan.recv(0.1) is None:
                    continue
        chan.close()
    finally:
        listener.close()


def test_unknown_token_rejected():
    listener = WorkerListener()
    try:
        chan = connect_channel(listener.address)
        chan.send(("hello", PROTOCOL_VERSION, "nobody-registered-me",
                   None, None))
        msg = _recv_frame(chan)
        assert msg is not None and msg[0] == "reject"
        assert "token" in msg[1]
        chan.close()
    finally:
        listener.close()


def test_handshake_welcome_carries_spec_and_fingerprint_mismatch_rejected():
    listener = WorkerListener()
    spec = echo_spec(delay_s=0.0, scale=5)
    t = SocketTransport(spec, CFG, metrics=MetricsRegistry(),
                        listener=listener, spawn=False)
    try:
        t.start(wait_ready=False)
        # first contact: hello with no fingerprint yet -> welcomed with the
        # spec + replica config to build from
        chan = connect_channel(listener.address)
        chan.send(("hello", PROTOCOL_VERSION, t.token, None, None))
        msg = _recv_frame(chan)
        assert msg is not None and msg[0] == "welcome"
        _tag, rid, shipped, cfg = msg[:4]
        assert rid == t.rid and cfg == CFG
        assert shipped == spec
        assert spec_fingerprint(shipped) == spec_fingerprint(spec)
        chan.close()
        # a reconnect announcing a *different* spec fingerprint (stale
        # worker from an old deployment) is refused at the door
        chan2 = connect_channel(listener.address)
        chan2.send(("hello", PROTOCOL_VERSION, t.token, "fn",
                    spec_fingerprint(echo_spec(scale=999))))
        msg2 = _recv_frame(chan2)
        assert msg2 is not None and msg2[0] == "reject"
        assert "fingerprint" in msg2[1]
        chan2.close()
        assert t.metrics.snapshot()["replica.handshake_rejects"] == 1
    finally:
        t._die(RuntimeError("test teardown"))
        listener.close()


def test_make_transport_socket_requires_spec():
    with pytest.raises(ValueError):
        make_transport("socket", backend=FnBackend(lambda ps: ps))


# ----------------------------------------------------------------------
# Round trip + telemetry over real spawned workers.

def test_socket_round_trip_and_worker_metrics_merge():
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m)
    for _ in range(2):
        r.add_replica(spec=echo_spec(delay_s=0.001), cfg=CFG,
                      transport="socket")
    reqs = [r.submit(i) for i in range(24)]
    assert [r.wait(q, 30.0) for q in reqs] == [2 * i for i in range(24)]
    # composite payloads/results keep exact types across TCP
    tup = r.submit((1, 2))
    out = r.wait(tup, 30.0)
    assert out == (1, 2, 1, 2) and isinstance(out, tuple)
    # worker-side batch histograms arrive via heartbeat snapshots, with
    # bucket counts, and merge into the cluster view
    assert _wait_until(
        lambda: r.cluster_snapshot().get("replica.batch_s.count", 0) > 0)
    snap = r.cluster_snapshot()
    assert snap["router.completed"] == 25
    assert any(k.startswith("replica.batch_s.le") for k in snap), \
        "worker histograms must ship bucket counts"
    r.stop()
    assert r.n_alive() == 0


# ----------------------------------------------------------------------
# Failure model.

def test_connection_drop_mid_batch_loses_zero_requests():
    """Sever the TCP connection (network partition) mid-load: every
    unacknowledged request spills immediately and completes elsewhere or
    on the reconnected worker — zero lost, zero double-completed."""
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m, max_retries=5)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.005), cfg=CFG,
                             transport="socket")
               for _ in range(2)]
    reqs = [r.submit(i) for i in range(40)]
    time.sleep(0.02)                      # mid-load…
    workers[0].sever_connection()         # …cut the wire, not the worker
    results = [r.wait(q, 30.0) for q in reqs]
    assert all(q.status is Status.OK for q in reqs), {q.status for q in reqs}
    assert results == [2 * i for i in range(40)]
    # the disconnect counter is incremented by the recv thread; don't race it
    assert _wait_until(
        lambda: m.snapshot().get("replica.disconnects", 0) >= 1)
    assert m.snapshot().get("router.failed", 0) == 0
    # the worker reconnects: the transport never left the pool
    assert _wait_until(
        lambda: m.snapshot().get("replica.reconnects", 0) >= 1
        and workers[0].connected()), "worker must reconnect"
    assert workers[0].alive and r.n_alive() == 2
    r.stop()


def test_sigkilled_worker_spills_zero_lost_then_heartbeat_timeout_kills():
    """SIGKILL the worker process: the drop spills everything unacked
    (zero lost), and with no reconnect the heartbeat monitor — not any
    process-liveness check — declares the transport dead."""
    cfg = ReplicaConfig(inbox_capacity=256, max_batch=4,
                        heartbeat_timeout_s=1.0)
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m, max_retries=5)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.005), cfg=cfg,
                             transport="socket")
               for _ in range(3)]
    reqs = [r.submit(i) for i in range(60)]
    time.sleep(0.02)
    workers[0].inject_crash()             # SIGKILL
    results = [r.wait(q, 30.0) for q in reqs]
    assert all(q.status is Status.OK for q in reqs), {q.status for q in reqs}
    assert results == [2 * i for i in range(60)]
    assert _wait_until(lambda: not workers[0].alive, timeout_s=5.0), \
        "heartbeat timeout must mark the transport dead"
    assert r.n_alive() == 2
    assert _wait_until(lambda: m.snapshot().get("replica.crashes", 0) == 1)
    assert m.snapshot().get("router.failed", 0) == 0
    r.stop()


def test_heartbeat_timeout_marks_open_but_silent_connection_dead():
    """An in-test 'worker' completes the handshake, reports ready, then
    goes silent while keeping TCP open: only heartbeat staleness can
    detect that, and it must."""
    listener = WorkerListener()
    cfg = ReplicaConfig(heartbeat_timeout_s=0.5)
    spilled = []
    t = SocketTransport(echo_spec(), cfg, metrics=MetricsRegistry(),
                        listener=listener, spawn=False,
                        on_spill=lambda reqs, w: spilled.extend(reqs))
    try:
        t.start(wait_ready=False)
        chan = connect_channel(listener.address)
        chan.send(("hello", PROTOCOL_VERSION, t.token, None, None))
        assert _recv_frame(chan)[0] == "welcome"
        chan.send(("ready",))
        assert t.wait_ready(5.0) and t.alive
        # silence: no heartbeats, connection stays open
        assert _wait_until(lambda: not t.alive, timeout_s=5.0), \
            "silent connection must die by heartbeat timeout"
        chan.close()
    finally:
        listener.close()


def test_reconnect_resumes_sessions():
    """A worker that reconnects after a drop keeps its rid, so rendezvous
    session placement is undisturbed: sessions homed on it return to it,
    sessions homed on the survivor never move."""
    m = MetricsRegistry()
    r = Router(policy="session_affinity", metrics=m, max_retries=5)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.001), cfg=CFG,
                             transport="socket")
               for _ in range(2)]
    keys = [f"user-{i}" for i in range(12)]
    reqs = [r.submit(i, session_key=keys[i % 12]) for i in range(24)]
    assert [r.wait(q, 30.0) for q in reqs] == [2 * i for i in range(24)]
    homes = {}
    for i, q in enumerate(reqs):
        k = keys[i % 12]
        assert homes.setdefault(k, q.replica_rid) == q.replica_rid, \
            f"session {k} bounced before any fault"
    assert len(set(homes.values())) == 2, "want sessions on both workers"
    victim = workers[0]
    victim.sever_connection()
    assert _wait_until(
        lambda: m.snapshot().get("replica.reconnects", 0) >= 1
        and victim.connected()), "worker must reconnect"
    assert victim.alive and r.n_alive() == 2
    # same keys, same homes — including on the reconnected worker
    reqs2 = [r.submit(100 + i, session_key=keys[i % 12]) for i in range(24)]
    assert all(r.wait(q, 30.0) == 2 * (100 + i)
               for i, q in enumerate(reqs2))
    for i, q in enumerate(reqs2):
        assert q.replica_rid == homes[keys[i % 12]], \
            f"session {keys[i % 12]} remapped across a mere reconnect"
    r.stop()


def test_socket_drain_finishes_outstanding():
    r = Router()
    w = r.add_replica(spec=echo_spec(delay_s=0.002), cfg=CFG,
                      transport="socket")
    reqs = [r.submit(i) for i in range(16)]
    r.remove_replica(w.rid, drain=True)
    for q in reqs:
        assert q.done.wait(15.0)
    assert all(q.status is Status.OK for q in reqs)
    assert [q.result for q in reqs] == [2 * i for i in range(16)]


def test_socket_soft_crash_spills_before_ack():
    m = MetricsRegistry()
    r = Router(policy="round_robin", metrics=m, max_retries=5)
    workers = [r.add_replica(spec=echo_spec(delay_s=0.01), cfg=CFG,
                             transport="socket")
               for _ in range(2)]
    reqs = [r.submit(i) for i in range(30)]
    time.sleep(0.02)
    workers[0].inject_crash(soft=True)
    results = [r.wait(q, 30.0) for q in reqs]
    assert all(q.status is Status.OK for q in reqs)
    assert results == [2 * i for i in range(30)]
    assert _wait_until(lambda: not workers[0].alive, timeout_s=5.0)
    assert r.n_alive() == 1
    assert _wait_until(lambda: m.snapshot().get("replica.crashes", 0) == 1)
    r.stop()


# ----------------------------------------------------------------------
# Artifact store.

def build_scaler_from_artifact(weights_path=None):
    """Module-level builder (spawn-importable): scale factor loaded from a
    weights file that reached this worker as an ``artifact:`` reference."""
    scale = int(np.load(weights_path)) if weights_path else 1
    return FnBackend(lambda ps: [p * scale for p in ps])


def test_artifact_store_roundtrip_and_corruption_refused(tmp_path):
    store = ArtifactStore(str(tmp_path / "cas"))
    digest = store.put_bytes(b"weights-blob")
    assert store.has(digest)
    assert store.read_bytes(digest) == b"weights-blob"
    assert store.put_bytes(b"weights-blob") == digest   # idempotent
    spec = BackendSpec("x:y", {"weights_path": artifact_ref(digest)})
    resolved = resolve_spec(spec, store)
    assert resolved.kwargs["weights_path"] == store.get_path(digest)
    # a miss with no fetcher is an explicit error
    missing = BackendSpec("x:y", {"weights_path": artifact_ref("0" * 64)})
    with pytest.raises(KeyError):
        resolve_spec(missing, store)
    # a fetch whose bytes do not hash to the requested digest is refused
    with pytest.raises(ValueError):
        resolve_spec(missing, store, fetch=lambda d: b"not-those-bytes")
    # a pre-planted cache file under the right name is a *miss*, not a
    # model: the verified fetch replaces it
    target = store.put_bytes(b"real-weights")
    with open(store.get_path(target), "wb") as f:
        f.write(b"planted-by-someone-else")
    planted = BackendSpec("x:y", {"weights_path": artifact_ref(target)})
    resolved2 = resolve_spec(planted, store, fetch=lambda d: b"real-weights")
    with open(resolved2.kwargs["weights_path"], "rb") as f:
        assert f.read() == b"real-weights"
    # refs untouched for plain kwargs
    plain = BackendSpec("x:y", {"seed": 3})
    assert resolve_spec(plain, store) is plain


def test_socket_worker_fetches_weights_by_hash(tmp_path):
    """End to end: the spec references weights by content hash; the
    spawned worker's store misses, fetches the blob over its own
    connection from the parent's store, verifies the digest, builds."""
    wpath = str(tmp_path / "w.npy")
    np.save(wpath, np.int64(7))
    store = ArtifactStore(str(tmp_path / "cas"))
    spec = BackendSpec("tests.test_socket_transport:build_scaler_from_artifact",
                       {"weights_path": store.put_ref(wpath)})
    r = Router()
    # through the Router front door: add_replica forwards artifacts=
    r.add_replica(spec=spec, cfg=CFG, transport="socket", artifacts=store)
    q = r.submit(6)
    assert r.wait(q, 20.0) == 42
    r.stop()
