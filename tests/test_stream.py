"""Stream runtime: scope-window / scope-file semantics vs brute force."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import PipelineConfig
from repro.core.stream import (StreamConfig, StreamRuntime,
                               find_sustainable_rate, init_ring, ring_append)
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus
from repro.models import svm as svm_mod

PCFG = PipelineConfig(feat_dim=128, claim_capacity=32, evid_capacity=32)


def make_stream(n_docs=3, spd=30):
    docs = synthetic_corpus(n_docs, spd, seed=4)
    X, keys, _ = corpus_arrays(docs, dim=PCFG.feat_dim)
    models, _ = margot_models(PCFG)
    ts = np.arange(len(keys), dtype=np.float32) * 0.5       # 2 inst/s
    return models, X, keys, ts


def scores_np(models, X):
    kw = dict(gamma=PCFG.svm_gamma, coef0=PCFG.svm_coef0, degree=PCFG.svm_degree)
    return (np.asarray(svm_mod.svm_score(models["claim"], X, **kw)),
            np.asarray(svm_mod.svm_score(models["evidence"], X, **kw)))


def test_window_scope_matches_brute_force():
    models, X, keys, ts = make_stream()
    scfg = StreamConfig(period=5.0, capacity=32, scope="window", window=8.0,
                        ring_capacity=256)
    rt = StreamRuntime(models, PCFG, scfg)

    got = set()
    for start in range(0, len(keys), 16):
        sl = slice(start, start + 16)
        sc, ok = rt.process_microbatch(X[sl], keys[sl], ts[sl])
        # decode pairs via ring contents: recompute from state
        st = rt.state
        cvalid = np.asarray(st.claims.valid)
        evalid = np.asarray(st.evidence.valid)
        for ci in np.nonzero(np.asarray(ok).any(axis=1))[0]:
            pass
        got |= {(round(float(st.claims.ts[i]), 3), round(float(st.evidence.ts[j]), 3))
                for i, j in zip(*np.nonzero(np.asarray(ok)))}

    # brute force: every (claim, evidence) whose timestamps fall in the same
    # window at the time the LATER of the two was processed
    c_sc, e_sc = scores_np(models, X)
    want = set()
    mb_edges = list(range(0, len(keys), 16))
    for mb_i, start in enumerate(mb_edges):
        end = min(start + 16, len(keys))
        now = ts[end - 1]
        cand_c = [i for i in range(end) if c_sc[i] > 0 and ts[i] > now - 8.0]
        cand_e = [j for j in range(end) if e_sc[j] > 0 and ts[j] > now - 8.0]
        for i in cand_c:
            for j in cand_e:
                if abs(ts[i] - ts[j]) <= 8.0:
                    s = float(svm_mod.link_score_matrix(
                        models["link"], X[i:i + 1], X[j:j + 1])[0, 0])
                    if s > 0:
                        want.add((round(float(ts[i]), 3), round(float(ts[j]), 3)))
    # every final-window brute-force pair must have been emitted at some point
    missing = want - got
    assert not missing, f"missing {len(missing)} of {len(want)}"


def test_file_scope_joins_past_claims_with_new_evidence():
    models, X, keys, ts = make_stream()
    scfg = StreamConfig(period=5.0, capacity=16, scope="file",
                        ring_capacity=256)
    rt = StreamRuntime(models, PCFG, scfg)
    c_sc, e_sc = scores_np(models, X)

    emitted = []
    for start in range(0, len(keys), 16):
        sl = slice(start, start + 16)
        sc, ok = rt.process_microbatch(X[sl], keys[sl], ts[sl])
        emitted.append(np.asarray(ok))
    # at least one cross-micro-batch (claim earlier, evidence later) pair
    later = [m.sum() for m in emitted[1:]]
    assert sum(later) > 0, "file scope should join old claims w/ new evidence"


def test_ring_append_wraps_and_evicts():
    ring = init_ring(8, 4)
    for rnd in range(3):
        feats = jnp.ones((4, 4)) * rnd
        ts = jnp.full((4,), float(rnd))
        keys = jnp.full((4,), rnd, jnp.int32)
        valid = jnp.ones((4,), bool)
        ring = ring_append(ring, feats, ts, keys, valid)
    assert int(ring.cursor) == 12 % 8
    # ring holds rounds 1..2 (round 0 evicted by wraparound)
    kept = set(np.asarray(ring.keys)[np.asarray(ring.valid)].tolist())
    assert kept == {1, 2}


def test_sustainable_rate_monotone_detection():
    models, X, keys, ts = make_stream(2, 20)
    scfg = StreamConfig(period=0.05, capacity=64, scope="window", window=1.0,
                        ring_capacity=128)

    def mk():
        return StreamRuntime(models, PCFG, scfg)

    def gen(n, t0):
        idx = np.random.RandomState(int(t0 * 10) + 1).randint(0, len(keys), n)
        return X[idx], keys[idx], np.full(n, t0, np.float32)

    rate = find_sustainable_rate(mk, gen, rates=[1, 10], mb_per_rate=3)
    assert rate >= 1.0
