"""Gradient compression: quantization error bounds, error feedback,
convergence, and the shard_map DP-reduction pattern."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from _hyp_compat import given, settings, st

from repro.optim.compression import (CompressedGrad, compression_ratio,
                                     dequantize, quantize, tree_dequantize,
                                     tree_quantize)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(1e-4, 1e3))
def test_quantize_error_bound(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (257,)) * scale
    c, res = quantize(g)
    err = jnp.abs(dequantize(c) - g)
    assert float(jnp.max(err)) <= float(c.scale) * 0.5 + 1e-9
    # residual == the quantization error (carried forward)
    np.testing.assert_allclose(res, g - dequantize(c), rtol=1e-5, atol=1e-8)


def test_error_feedback_removes_bias():
    """With error feedback, the accumulated dequantized sum tracks the true
    gradient sum even when each step's gradient is below one quantum."""
    g = jnp.full((64,), 1e-3)
    big = jnp.zeros((64,)).at[0].set(1.0)      # forces a coarse scale
    res = jnp.zeros((64,))
    acc = jnp.zeros((64,))
    for _ in range(100):
        c, res = quantize(g + big * 0.0, res)  # scale set by residual growth
        acc = acc + dequantize(c)
    np.testing.assert_allclose(acc[1:], 100 * g[1:], rtol=0.05)


def test_sgd_with_compression_converges():
    w = jnp.array([2.0, -3.0, 1.0])
    target = jnp.array([0.5, 0.5, 0.5])
    res = jax.tree_util.tree_map(jnp.zeros_like, {"w": w})
    params = {"w": w}
    for step in range(400):
        g = jax.tree_util.tree_map(lambda p, t: 2 * (p - t), params,
                                   {"w": target})
        c, res = tree_quantize(g, res)
        g_hat = tree_dequantize(c)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg,
                                        params, g_hat)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_compression_ratio():
    grads = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24, 24))}
    r = compression_ratio(grads)
    assert 0.25 <= r < 0.26


DP_REDUCE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.sharding import shard_map_compat
from repro.optim.compression import quantize, compressed_psum, dequantize

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((8,), ("data",))
G = jax.random.normal(jax.random.PRNGKey(0), (8, 512))   # per-worker grads

def reduce_fn(g):
    c, _ = quantize(g[0])
    val, _ = compressed_psum(c, "data")
    return val[None] / 8.0

fn = shard_map_compat(reduce_fn, mesh=mesh, in_specs=(P("data", None),),
                      out_specs=P("data", None))
out = jax.jit(fn)(G)
true = jnp.mean(G, axis=0)
err = float(jnp.max(jnp.abs(out[0] - true)))
tol = float(jnp.max(jnp.abs(G))) / 127.0
assert err <= tol, (err, tol)
print("DPREDUCE-OK", err)
"""


def test_compressed_dp_reduction_shard_map():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", DP_REDUCE], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "DPREDUCE-OK" in r.stdout
