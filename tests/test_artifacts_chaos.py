"""ArtifactStore under concurrent fetches: write-to-temp + digest
re-verify + atomic rename must keep the store uncorrupted when N workers
race to materialize the same artifact (ROADMAP chaos item)."""
import hashlib
import os
import threading
import time

import pytest

from repro.cluster.artifacts import (ArtifactStore, resolve_spec,
                                     sha256_bytes)
from repro.cluster.backends import BackendSpec


def _spec(digest):
    return BackendSpec("tests.test_artifacts_chaos:_unused",
                       {"weights_path": f"artifact:{digest}"}, "fn")


def _unused():                     # spec target never built in these tests
    raise AssertionError


def test_concurrent_fetch_same_hash(tmp_path):
    """Two workers sharing one store directory resolve the same missing
    artifact simultaneously through a slow fetch; both succeed and the
    installed file is byte-exact."""
    payload = os.urandom(1 << 18)
    digest = sha256_bytes(payload)
    barrier = threading.Barrier(2)
    fetches = []

    def fetch(sha):
        barrier.wait()                 # maximal overlap
        fetches.append(sha)
        time.sleep(0.02)               # keep both writes in flight together
        return payload

    results, errors = [], []

    def worker():
        store = ArtifactStore(str(tmp_path))   # own handle, shared root
        try:
            resolved = resolve_spec(_spec(digest), store, fetch)
            results.append(resolved.kwargs["weights_path"])
        except BaseException as e:     # noqa: BLE001
            errors.append(e)

    ts = [threading.Thread(target=worker) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(30)
    assert not errors
    assert len(results) == 2 and len(fetches) == 2
    with open(results[0], "rb") as f:
        assert hashlib.sha256(f.read()).hexdigest() == digest
    # no stray temp files leaked by the race
    assert [p for p in os.listdir(tmp_path) if p.endswith(".tmp")] == []


def test_many_workers_one_slow_fetch(tmp_path):
    """An 8-way stampede on one digest: every resolution returns a path
    whose content verifies, regardless of interleaving."""
    payload = os.urandom(1 << 16)
    digest = sha256_bytes(payload)
    start = threading.Barrier(8)
    ok = []

    def worker(i):
        store = ArtifactStore(str(tmp_path))
        start.wait()
        resolved = resolve_spec(_spec(digest), store,
                                lambda sha: (time.sleep(0.001 * (i % 4)),
                                             payload)[1])
        with open(resolved.kwargs["weights_path"], "rb") as f:
            ok.append(hashlib.sha256(f.read()).hexdigest() == digest)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(30)
    assert ok == [True] * 8


def test_torn_write_is_refused(tmp_path, monkeypatch):
    """A write whose bytes do not reach disk intact (simulated short
    write) must not be published under the digest: the install verifies
    the on-disk content before the atomic rename."""
    store = ArtifactStore(str(tmp_path))
    payload = b"x" * 4096
    digest = sha256_bytes(payload)

    real_fdopen = os.fdopen

    def torn_fdopen(fd, mode="r", *a, **kw):
        f = real_fdopen(fd, mode, *a, **kw)
        if "w" in mode and "b" in mode:
            real_write = f.write
            f.write = lambda data: real_write(data[:len(data) // 2])
        return f

    monkeypatch.setattr(os, "fdopen", torn_fdopen)
    with pytest.raises(IOError, match="verification failed"):
        store.put_bytes(payload)
    monkeypatch.undo()
    assert not store.has(digest)           # nothing published
    # a healthy retry succeeds and verifies
    assert store.put_bytes(payload) == digest
    assert store.has(digest)


def test_preplanted_corruption_is_replaced(tmp_path):
    """A wrong-content file already sitting under the digest (pre-planted
    or corrupted at rest) is overwritten by a verified put and treated as
    a miss by resolve."""
    store = ArtifactStore(str(tmp_path))
    payload = b"real weights"
    digest = sha256_bytes(payload)
    with open(os.path.join(str(tmp_path), digest), "wb") as f:
        f.write(b"evil")
    resolved = resolve_spec(_spec(digest), store, lambda sha: payload)
    with open(resolved.kwargs["weights_path"], "rb") as f:
        assert f.read() == payload


def test_put_file_streams_and_verifies(tmp_path):
    store = ArtifactStore(str(tmp_path / "store"))
    src = tmp_path / "weights.bin"
    payload = os.urandom(3 << 20)          # multiple stream chunks
    src.write_bytes(payload)
    digest = store.put_file(str(src))
    assert digest == sha256_bytes(payload)
    assert store.read_bytes(digest) == payload
    # idempotent re-put short-circuits on the verified existing file
    assert store.put_file(str(src)) == digest


def test_corrupt_fetch_rejected_concurrently(tmp_path):
    """One worker's fetch returns corrupt bytes while another's returns
    the real artifact: the corrupt resolution fails loudly, the good one
    succeeds, and the store ends up valid."""
    payload = os.urandom(1 << 14)
    digest = sha256_bytes(payload)
    outcomes = {}

    def worker(name, data):
        store = ArtifactStore(str(tmp_path))
        try:
            resolve_spec(_spec(digest), store, lambda sha: data)
            outcomes[name] = "ok"
        except ValueError:
            outcomes[name] = "rejected"

    ts = [threading.Thread(target=worker, args=("bad", b"garbage")),
          threading.Thread(target=worker, args=("good", payload))]
    for t in ts:
        t.start()
    for t in ts:
        t.join(30)
    assert outcomes["good"] == "ok"
    assert outcomes["bad"] in ("rejected", "ok")   # may hit good's install
    store = ArtifactStore(str(tmp_path))
    assert store.read_bytes(digest) == payload
