"""Roofline summary: reads the dry-run artifacts (results/dryrun/*.json) and
emits one line per (arch x shape x mesh) cell with the three roofline terms
and the dominant bottleneck.  The numbers are produced by
``python -m repro.launch.dryrun`` (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def run(quick: bool = False):
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        emit("roofline/none", 0.0, "no dry-run artifacts; run repro.launch.dryrun")
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        if d.get("skipped"):
            emit(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}", 0.0, "skipped")
            continue
        if not d.get("ok"):
            emit(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}", 0.0,
                 f"FAILED:{d.get('error','')[:60]}")
            continue
        t_dom = max(d["t_compute"], d["t_memory"], d["t_collective"])
        emit(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}/{d['policy']}",
             t_dom * 1e6,
             f"dom={d['dominant']};tc={d['t_compute']:.3f};"
             f"tm={d['t_memory']:.3f};tx={d['t_collective']:.3f};"
             f"useful={d['useful_ratio']:.2f}")


if __name__ == "__main__":
    run()
