"""Single-replica LM serving hot path: fused on-device decode loop vs. the
per-token reference engine.

Every replica-count number in ``BENCH_cluster.json`` multiplies this base,
so the fused/reference ratio here is the PR's whole claim: (1) in-jit
sampling ships ``(slots,)`` token ids instead of ``(slots, vocab)`` logits,
(2) donated caches update in place, (3) a ``lax.fori_loop`` runs
``sync_every`` (K) decode steps per host sync, (4) admits run as bucketed
batch prefill.  Both engines run the identical workload (greedy, same
model/config/prompts) and, by the parity tests
(``tests/test_serving_fused.py``), emit identical tokens — the ratio is
pure hot-path cost.

    PYTHONPATH=src python -m benchmarks.bench_serving [--quick]

Machine-readable results land in ``BENCH_serving.json`` at the repo root
(merged across runs, like ``BENCH_cluster.json``).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import bench_json_path, emit, write_bench_json

JSON_PATH = bench_json_path("BENCH_serving.json")


def _bench_engine(params, cfg, scfg, prompts, max_new: int):
    """Tokens/s and p50 latency through one engine.

    The identical workload runs twice and the second (warm) pass is timed:
    a serving engine compiles each shape once per deployment and then
    serves millions of tokens, so steady-state throughput — not first-call
    XLA compilation — is the quantity every replica-count number scales."""
    from repro.serving import Engine

    eng = Engine(params, cfg, scfg)
    warm = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run_until_drained()
    assert all(r.done for r in warm)
    eng.finished.clear()
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    toks = sum(r.decoded for r in reqs)
    lat = sorted(r.done_t - r.submit_t for r in reqs)
    return {"tok_per_s": toks / wall, "decoded_tokens": toks,
            "wall_s": wall, "p50_lat_s": lat[len(lat) // 2]}


def run(quick: bool = False, json_path: str = JSON_PATH,
        arch: str = "internlm2-1.8b", sync_every: int = 8):
    import jax

    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import ServeConfig

    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    n_req = 6 if quick else 12
    max_new = 24 if quick else 48
    # mixed prompt lengths: exercises the power-of-two prefill buckets on
    # the fused path and per-length compiles on the reference path
    prompts = [rng.randint(0, cfg.vocab,
                           size=rng.randint(5, 13)).astype(np.int32)
               for _ in range(n_req)]

    common = dict(max_len=96, slots=4)
    res = {}
    for label, scfg in (
            ("reference", ServeConfig(fused=False, **common)),
            ("fused", ServeConfig(fused=True, sync_every=sync_every,
                                  **common))):
        res[label] = _bench_engine(params, cfg, scfg, prompts, max_new)
        emit(f"serving/engine/{label}",
             1e6 * res[label]["wall_s"] / max(res[label]["decoded_tokens"], 1),
             f"tok_per_s={res[label]['tok_per_s']:.1f};"
             f"p50_lat_s={res[label]['p50_lat_s']:.3f}")
    speedup = res["fused"]["tok_per_s"] / res["reference"]["tok_per_s"]
    emit("serving/engine/fused_speedup", 0.0, f"speedup={speedup:.2f}x")

    out = {"meta": {"arch": arch, "quick": quick, "n_req": n_req,
                    "max_new": max_new, "sync_every": sync_every,
                    "slots": common["slots"], "max_len": common["max_len"],
                    "cpu_count": os.cpu_count(), "unix_time": time.time()},
           "reference": res["reference"], "fused": res["fused"],
           "speedup": speedup}
    if json_path:
        # keep the full-run numbers when a --quick smoke runs later: merge
        # under a mode key instead of clobbering the file
        mode = "quick" if quick else "full"
        write_bench_json(json_path, lambda prev: {**prev, mode: out})
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (CI smoke)")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="K: fused decode steps per host sync")
    args = ap.parse_args()
    run(quick=args.quick, sync_every=args.sync_every)
