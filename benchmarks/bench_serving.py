"""Single-replica LM serving hot path: fused on-device decode loop vs. the
per-token reference engine.

Every replica-count number in ``BENCH_cluster.json`` multiplies this base,
so the fused/reference ratio here is the PR's whole claim: (1) in-jit
sampling ships ``(slots,)`` token ids instead of ``(slots, vocab)`` logits,
(2) donated caches update in place, (3) a ``lax.fori_loop`` runs
``sync_every`` (K) decode steps per host sync, (4) admits run as bucketed
batch prefill.  Both engines run the identical workload (greedy, same
model/config/prompts) and, by the parity tests
(``tests/test_serving_fused.py``), emit identical tokens — the ratio is
pure hot-path cost.

    PYTHONPATH=src python -m benchmarks.bench_serving [--quick]

Machine-readable results land in ``BENCH_serving.json`` at the repo root
(merged across runs, like ``BENCH_cluster.json``).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import bench_json_path, emit, write_bench_json

JSON_PATH = bench_json_path("BENCH_serving.json")


def _bench_engine(params, cfg, scfg, prompts, max_new: int, reps: int = 5):
    """Tokens/s and p50 latency through one engine.

    The identical workload runs once unmeasured (warm), then ``reps``
    timed passes; the best pass is reported.  A serving engine compiles
    each shape once per deployment and then serves millions of tokens,
    so steady-state throughput — not first-call XLA compilation, nor a
    pass perturbed by allocator growth or OS scheduling on a shared
    box — is the quantity every replica-count number scales.  Min (not
    mean) because the noise here is strictly additive."""
    from repro.serving import Engine

    eng = Engine(params, cfg, scfg)
    warm = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run_until_drained()
    assert all(r.done for r in warm)
    best = None
    for _ in range(reps):
        eng.finished.clear()
        reqs = [eng.submit(p, max_new=max_new) for p in prompts]
        t0 = time.perf_counter()
        eng.run_until_drained()
        wall = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        if best is None or wall < best[0]:
            best = (wall, reqs)
    wall, reqs = best
    toks = sum(r.decoded for r in reqs)
    lat = sorted(r.done_t - r.submit_t for r in reqs)
    return {"tok_per_s": toks / wall, "decoded_tokens": toks,
            "wall_s": wall, "p50_lat_s": lat[len(lat) // 2],
            "_tokens": [r.out_tokens for r in reqs]}


def _bench_paired(engines, prompts, max_new: int, reps: int = 10):
    """Interleave timed passes of several live engines rep-by-rep and
    report each engine's best pass.

    Comparing two configs by timing one engine's reps and then the
    other's lets minutes-scale load drift on a shared box land entirely
    on one side — the ratio then measures the box, not the engines.
    Alternating passes makes every config sample the same noise windows,
    so per-config minima stay comparable."""
    best = {}
    for label, eng in engines:
        warm = [eng.submit(p.copy(), max_new=max_new) for p in prompts]
        eng.run_until_drained()
        assert all(r.done for r in warm)
    for _ in range(reps):
        for label, eng in engines:
            eng.finished.clear()
            reqs = [eng.submit(p.copy(), max_new=max_new) for p in prompts]
            t0 = time.perf_counter()
            eng.run_until_drained()
            wall = time.perf_counter() - t0
            assert all(r.done for r in reqs)
            if label not in best or wall < best[label][0]:
                best[label] = (wall, reqs)
    out = {}
    for label, (wall, reqs) in best.items():
        toks = sum(r.decoded for r in reqs)
        lat = sorted(r.done_t - r.submit_t for r in reqs)
        out[label] = {"tok_per_s": toks / wall, "decoded_tokens": toks,
                      "wall_s": wall, "p50_lat_s": lat[len(lat) // 2],
                      "_tokens": [r.out_tokens for r in reqs]}
    return out


def _drain_tracking_concurrency(eng, prompts, max_new: int):
    """Submit everything, drain, and record the peak number of
    simultaneously-active slots (the concurrency the engine sustained)."""
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    peak = 0
    steps = 0
    while (eng.queue or any(r is not None for r in eng.active)) \
            and steps < 10_000:
        eng.step()
        peak = max(peak, sum(r is not None for r in eng.active))
        steps += 1
    assert all(r.done for r in reqs)
    return reqs, peak


def run_paged(quick: bool = False, json_path: str = JSON_PATH,
              arch: str = "internlm2-1.8b", sync_every: int = 8):
    """Paged-KV scenarios: (1) fused-vs-paged throughput on the identical
    workload (parity-checked greedy tokens), (2) max concurrent sessions
    at *fixed KV memory* — the dense layout pins slots x max_len tokens,
    the paged pool holds the same token budget but admits sessions by
    their actual footprint, (3) a shared-prefix workload (80% common
    prompt) measuring prefix-cache hit rate and prefill tokens saved."""
    import jax

    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    max_len, base_slots, bs = 96, 4, 16
    out = {"meta": {"arch": arch, "quick": quick, "max_len": max_len,
                    "base_slots": base_slots, "block_size": bs,
                    "sync_every": sync_every, "cpu_count": os.cpu_count(),
                    "unix_time": time.time()}}

    # -- 1. throughput + parity on the dense benchmark's workload --------
    n_req = 6 if quick else 12
    max_new = 24 if quick else 48
    prompts = [rng.randint(0, cfg.vocab,
                           size=rng.randint(5, 13)).astype(np.int32)
               for _ in range(n_req)]
    engines = [
        ("dense_fused", Engine(params, cfg,
                               ServeConfig(max_len=max_len,
                                           slots=base_slots,
                                           sync_every=sync_every))),
        ("paged", Engine(params, cfg,
                         ServeConfig(max_len=max_len, slots=base_slots,
                                     sync_every=sync_every, paged=True,
                                     block_size=bs)))]
    res = _bench_paired(engines, prompts, max_new,
                        reps=5 if quick else 15)
    del engines
    toks_by_mode = {}
    for label in ("dense_fused", "paged"):
        toks_by_mode[label] = res[label].pop("_tokens")
        emit(f"serving/paged/{label}",
             1e6 * res[label]["wall_s"] / max(res[label]["decoded_tokens"], 1),
             f"tok_per_s={res[label]['tok_per_s']:.1f}")
    assert toks_by_mode["dense_fused"] == toks_by_mode["paged"], \
        "paged engine lost token parity with the dense fused oracle"
    out["throughput"] = res
    out["paged_vs_dense_tok_ratio"] = (res["paged"]["tok_per_s"] /
                                       res["dense_fused"]["tok_per_s"])

    # -- 2. concurrent sessions at fixed KV memory -----------------------
    # budget: the tokens dense reserves for base_slots sessions.  Sessions
    # are realistically short (prompt+decode << max_len), which is exactly
    # the regime where dense slot reservation wastes the pool.
    budget_tokens = base_slots * max_len
    sess_prompt, sess_new = 10, 16 if quick else 20
    capacity = {"dense_max_concurrent": base_slots,
                "budget_tokens": budget_tokens}
    best = 0
    for mult in (1, 2, 3, 4, 5, 6):
        slots = base_slots * mult
        scfg = ServeConfig(max_len=max_len, slots=slots,
                           sync_every=sync_every, paged=True, block_size=bs,
                           kv_blocks=budget_tokens // bs,
                           prefix_cache=False)
        eng = Engine(params, cfg, scfg)
        sess = [rng.randint(0, cfg.vocab, size=sess_prompt).astype(np.int32)
                for _ in range(slots)]
        # pool exhaustion mid-decode no longer raises: the engine finishes
        # the victim with finish_reason="kv_pool_exhausted" and keeps the
        # rest of the batch running, so the sweep reads the counter instead
        # of catching an exception
        reqs, peak = _drain_tracking_concurrency(eng, sess, sess_new)
        deferred = eng.metrics.counter("engine.admit_deferred_kv").value
        exhausted = eng.metrics.counter("engine.kv_pool_exhausted").value
        sustained = peak == slots and deferred == 0 and exhausted == 0
        capacity[f"x{mult}"] = {"slots": slots, "peak_concurrent": peak,
                                "admit_deferred": int(deferred),
                                "pool_exhausted": int(exhausted),
                                "sustained": bool(sustained)}
        if sustained:
            best = max(best, peak)
        else:
            break
    capacity["paged_max_concurrent"] = best
    capacity["capacity_ratio"] = best / base_slots
    emit("serving/paged/capacity", 0.0,
         f"dense={base_slots};paged={best};ratio={best / base_slots:.1f}x")
    out["capacity"] = capacity

    # -- 3. shared-prefix workload (80% common prompt) -------------------
    n_sess = 4 if quick else 8
    common = rng.randint(0, cfg.vocab, size=32).astype(np.int32)
    tails = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
             for _ in range(n_sess)]
    shared = [np.concatenate([common, t]) for t in tails]   # 80% common
    engines = {}
    for label, use_cache in (("prefix_cache", True), ("no_cache", False)):
        scfg = ServeConfig(max_len=max_len, slots=base_slots,
                           sync_every=sync_every, paged=True, block_size=bs,
                           prefix_cache=use_cache)
        eng = Engine(params, cfg, scfg)
        warm = [eng.submit(p.copy(), max_new=8) for p in shared]
        eng.run_until_drained()
        assert all(r.done for r in warm)
        engines[label] = eng
    # steady state: the cache is populated (and the jits warm) — the timed
    # passes are what a long-lived service sees per request wave.  The two
    # modes interleave inside each rep (same slice of machine time) and
    # min-wall is the noise-robust estimator.
    prefix_res = {}
    reps = 3 if quick else 5
    walls = {label: [] for label in engines}
    for _ in range(reps):
        for label, eng in engines.items():
            eng.finished.clear()
            reqs = [eng.submit(p.copy(), max_new=8) for p in shared]
            t0 = time.perf_counter()
            eng.run_until_drained()
            walls[label].append(time.perf_counter() - t0)
            assert all(r.done for r in reqs)
    for label, eng in engines.items():
        hit = eng.metrics.counter("engine.prefix_hit_blocks").value
        looked = eng.metrics.counter("engine.prefix_lookup_blocks").value
        prefix_res[label] = {
            "wall_s": min(walls[label]),
            "wall_all_s": walls[label],
            "prefix_hit_rate": hit / looked if looked else 0.0,
            "prefill_tokens_saved":
                eng.metrics.counter("engine.prefill_tokens_saved").value,
        }
    # the cache must never cost throughput: hashing is memoized off the
    # admit path (kvpool.hash_token_blocks_memo), so a cache-enabled wave
    # does strictly less prefill work than a cold one (10% timer slack)
    assert prefix_res["prefix_cache"]["wall_s"] <= \
        1.10 * prefix_res["no_cache"]["wall_s"], \
        (f"prefix cache slowed the serving wave: "
         f"{prefix_res['prefix_cache']['wall_s']:.4f}s vs "
         f"{prefix_res['no_cache']['wall_s']:.4f}s without the cache")
    emit("serving/paged/shared_prefix", 0.0,
         f"hit_rate={prefix_res['prefix_cache']['prefix_hit_rate']:.2f};"
         f"tokens_saved="
         f"{prefix_res['prefix_cache']['prefill_tokens_saved']:.0f}")
    out["shared_prefix"] = prefix_res

    # -- 4. speculative multi-token decode -------------------------------
    # n-gram drafting only pays when history predicts the future, and the
    # uniform-random prompts above have no such structure.  This scenario
    # serves *continuations*: a probe generation produces one long greedy
    # stream, and each request is a deep prefix cut of it asked to keep
    # going — the regime speculation targets (templated / re-submitted
    # generations), where the bigram draft table is highly predictive.
    from repro.serving import make_engine_fns

    spec_len = 256
    n_cont = 4 if quick else 8
    cont_new = 48 if quick else 96
    probe_scfg = ServeConfig(max_len=spec_len, slots=1,
                             sync_every=sync_every, paged=True,
                             block_size=bs)
    probe_eng = Engine(params, cfg, probe_scfg)
    # dedicated probe seed: the greedy stream must settle into its cycle
    # before the cut region for the draft to have anything to latch onto
    # (the seed is pinned so the scenario doesn't inherit whatever rng
    # state the earlier parts left behind)
    seed = np.random.RandomState(42).randint(
        0, cfg.vocab, size=8).astype(np.int32)
    pr = probe_eng.submit(seed, max_new=140)
    probe_eng.run_until_drained()
    full = np.concatenate([seed, np.asarray(pr.out_tokens, np.int32)])
    cuts = [full[:120 + 3 * i].copy() for i in range(n_cont)]
    del probe_eng
    spec_engines = []
    for label, speculative in (("paged", False), ("spec", True)):
        scfg = ServeConfig(max_len=spec_len, slots=base_slots,
                           sync_every=sync_every, paged=True, block_size=bs,
                           speculative=speculative)
        spec_engines.append((label, Engine(params, cfg, scfg,
                                           shared_fns=make_engine_fns(
                                               cfg, scfg))))
    spec_res = _bench_paired(spec_engines, cuts, cont_new,
                             reps=3 if quick else 8)
    spec_toks = {label: spec_res[label].pop("_tokens")
                 for label, _ in spec_engines}
    seng = dict(spec_engines)["spec"]
    acc = seng.metrics.counter("engine.spec_accepted").value
    prop = seng.metrics.counter("engine.spec_proposed").value
    spec_res["spec"]["accepted"] = int(acc)
    spec_res["spec"]["proposed"] = int(prop)
    spec_res["spec"]["accept_rate"] = acc / prop if prop else 0.0
    spec_res["spec"]["speculative"] = bool(seng.speculative)
    del spec_engines, seng
    for label in ("paged", "spec"):
        emit(f"serving/spec/{label}",
             1e6 * spec_res[label]["wall_s"]
             / max(spec_res[label]["decoded_tokens"], 1),
             f"tok_per_s={spec_res[label]['tok_per_s']:.1f}")
    assert spec_toks["spec"] == spec_toks["paged"], \
        "speculative decode lost token parity with the paged oracle"
    spec_res["spec_effective_tok_ratio"] = (
        spec_res["spec"]["tok_per_s"] / spec_res["paged"]["tok_per_s"])
    emit("serving/spec/effective_ratio", 0.0,
         f"ratio={spec_res['spec_effective_tok_ratio']:.2f}x;"
         f"accept={spec_res['spec']['accept_rate']:.2f}")
    out["speculative"] = spec_res
    out["spec_effective_tok_ratio"] = spec_res["spec_effective_tok_ratio"]

    if json_path:
        mode = "paged_quick" if quick else "paged"
        write_bench_json(json_path, lambda prev: {**prev, mode: out})
    return out


def run_trace_overhead(quick: bool = False, json_path: str = JSON_PATH,
                       arch: str = "internlm2-1.8b", sync_every: int = 8):
    """Cost of the observability layer on the fused hot path: the identical
    workload runs under (a) the disabled null tracer — every span call is a
    no-op — and (b) a full-sampling tracer recording the complete span tree
    per request.  The two overhead fractions back the acceptance bounds
    (<=1% disabled, <=5% at sample rate 1.0); they are recorded, not
    asserted, because single-digit percentages drown in CI timer noise."""
    import jax

    from repro.cluster.tracing import Tracer, current_tracer, set_tracer
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import ServeConfig

    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    n_req = 6 if quick else 12
    max_new = 24 if quick else 48
    prompts = [rng.randint(0, cfg.vocab,
                           size=rng.randint(5, 13)).astype(np.int32)
               for _ in range(n_req)]
    scfg = ServeConfig(max_len=96, slots=4, sync_every=sync_every)

    from repro.serving import Engine

    reps = 4 if quick else 8
    tracers = {"disabled": Tracer(enabled=False),
               "sampled_1_0": Tracer(enabled=True, sample_rate=1.0,
                                     capacity=1 << 20)}
    prev = current_tracer()
    res = {}
    try:
        # ONE engine, interleaved A/B passes: separate engine builds drift
        # by far more than the span cost (each timed pass is tens of ms),
        # so the two modes must share compile state, caches, and the same
        # slice of machine time; min-wall is the noise-robust estimator
        eng = Engine(params, cfg, scfg)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        eng.run_until_drained()            # warm: compile both shapes
        walls = {k: [] for k in tracers}
        toks = {k: 0 for k in tracers}
        for _ in range(reps):
            for label, tracer in tracers.items():
                set_tracer(tracer)
                eng.finished.clear()
                reqs = [eng.submit(p, max_new=max_new) for p in prompts]
                t0 = time.perf_counter()
                eng.run_until_drained()
                walls[label].append(time.perf_counter() - t0)
                assert all(r.done for r in reqs)
                toks[label] = sum(r.decoded for r in reqs)
        for label, tracer in tracers.items():
            wall = min(walls[label])
            res[label] = {"tok_per_s": toks[label] / wall,
                          "decoded_tokens": toks[label], "wall_s": wall,
                          "wall_all_s": walls[label],
                          "spans_recorded": len(tracer.spans())}
            emit(f"serving/trace/{label}",
                 1e6 * wall / max(toks[label], 1),
                 f"tok_per_s={res[label]['tok_per_s']:.1f}")
    finally:
        set_tracer(prev)

    base = res["disabled"]["tok_per_s"]
    out = {"meta": {"arch": arch, "quick": quick, "n_req": n_req,
                    "max_new": max_new, "sync_every": sync_every,
                    "cpu_count": os.cpu_count(), "unix_time": time.time()},
           "disabled": res["disabled"], "sampled_1_0": res["sampled_1_0"],
           "overhead_frac_sampled":
               1.0 - res["sampled_1_0"]["tok_per_s"] / base}
    emit("serving/trace/overhead", 0.0,
         f"sampled={out['overhead_frac_sampled'] * 100:.1f}%")
    if json_path:
        write_bench_json(json_path,
                         lambda prev: {**prev, "trace_overhead": out})
    return out


def run_telemetry_overhead(quick: bool = False, json_path: str = JSON_PATH,
                           arch: str = "internlm2-1.8b",
                           sync_every: int = 8):
    """Cost of the PR 10 telemetry stack on the fused hot path: the
    identical workload on ONE metered engine runs (a) bare — registry
    attached but nothing reading it — and (b) with the full stack live:
    a ``TelemetrySampler`` at the production 250ms heartbeat cadence,
    the SLO burn-rate engine on every tick, the HTTP stats endpoint up,
    and a background poller fetching ``/metrics`` +
    ``/timeseries.json`` over real HTTP at dashboard-refresh cadence
    (every 500ms).
    Interleaved rep-by-rep (PR 6 trace-overhead style), but each timed
    block is MANY back-to-back waves, not one: a single wave here lasts
    well under the 250ms sampling period, so a short pass either
    contains a tick or not — and ``sampler.start()`` immediately before
    the pass would guarantee it does, biasing the estimate high.  Long
    blocks span several periods, so the periodic cost lands at its true
    duty cycle.  Min-wall per side over the blocks.  The overhead
    fraction is recorded under
    ``BENCH_serving.json["telemetry_overhead"]`` against the <=2%
    acceptance bound; recorded, not asserted, because single-digit
    percentages drown in CI timer noise."""
    import threading
    import urllib.request

    import jax

    from repro.cluster import (MetricsRegistry, SLOEngine, StatsServer,
                               TelemetrySampler, TimeSeriesStore,
                               test_scaled_objective)
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    n_req = 6 if quick else 12
    max_new = 24 if quick else 48
    prompts = [rng.randint(0, cfg.vocab,
                           size=rng.randint(5, 13)).astype(np.int32)
               for _ in range(n_req)]
    scfg = ServeConfig(max_len=96, slots=4, sync_every=sync_every)

    metrics = MetricsRegistry()
    eng = Engine(params, cfg, scfg, metrics=metrics)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    eng.run_until_drained()                # warm: compile both shapes

    store = TimeSeriesStore()
    slo = SLOEngine([test_scaled_objective()], metrics)
    sampler = TelemetrySampler(metrics.snapshot, store, registry=metrics,
                               slo=slo, period_s=0.25)
    server = StatsServer(metrics.snapshot, store, slo=slo).start()
    poll_stop = threading.Event()

    def _poll():
        while not poll_stop.wait(0.5):
            for route in ("/metrics", "/timeseries.json"):
                try:
                    with urllib.request.urlopen(server.url + route,
                                                timeout=5.0) as r:
                        r.read()
                except OSError:
                    pass

    reps = 3 if quick else 5
    waves = 8 if quick else 10
    walls = {"bare": [], "telemetry": []}
    toks = {"bare": 0, "telemetry": 0}
    try:
        for _ in range(reps):
            for label in ("bare", "telemetry"):
                poller = None
                if label == "telemetry":
                    sampler.start()
                    poll_stop.clear()
                    poller = threading.Thread(target=_poll, daemon=True)
                    poller.start()
                block_toks = 0
                t0 = time.perf_counter()
                for _w in range(waves):
                    eng.finished.clear()
                    reqs = [eng.submit(p, max_new=max_new)
                            for p in prompts]
                    eng.run_until_drained()
                    assert all(r.done for r in reqs)
                    block_toks += sum(r.decoded for r in reqs)
                walls[label].append(time.perf_counter() - t0)
                if label == "telemetry":
                    poll_stop.set()
                    poller.join(timeout=5.0)
                    sampler.stop()
                toks[label] = block_toks
    finally:
        poll_stop.set()
        sampler.stop()
        server.stop()

    res = {}
    for label in ("bare", "telemetry"):
        wall = min(walls[label])
        res[label] = {"tok_per_s": toks[label] / wall,
                      "decoded_tokens": toks[label], "wall_s": wall,
                      "wall_all_s": walls[label]}
        emit(f"serving/telemetry/{label}",
             1e6 * wall / max(toks[label], 1),
             f"tok_per_s={res[label]['tok_per_s']:.1f}")
    base = res["bare"]["tok_per_s"]
    out = {"meta": {"arch": arch, "quick": quick, "n_req": n_req,
                    "max_new": max_new, "sync_every": sync_every,
                    "waves_per_block": waves, "reps": reps,
                    "sample_period_s": sampler.period_s,
                    "poll_period_s": 0.5,
                    "cpu_count": os.cpu_count(), "unix_time": time.time()},
           "bare": res["bare"], "telemetry": res["telemetry"],
           "sampler_ticks": sampler.ticks,
           "store_points": store.n_points,
           "overhead_frac":
               1.0 - res["telemetry"]["tok_per_s"] / base}
    emit("serving/telemetry/overhead", 0.0,
         f"overhead={out['overhead_frac'] * 100:.1f}% (bound: 2%)")
    if json_path:
        write_bench_json(json_path,
                         lambda prev: {**prev, "telemetry_overhead": out})
    return out


def run(quick: bool = False, json_path: str = JSON_PATH,
        arch: str = "internlm2-1.8b", sync_every: int = 8):
    import jax

    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import ServeConfig

    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    n_req = 6 if quick else 12
    max_new = 24 if quick else 48
    # mixed prompt lengths: exercises the power-of-two prefill buckets on
    # the fused path and per-length compiles on the reference path
    prompts = [rng.randint(0, cfg.vocab,
                           size=rng.randint(5, 13)).astype(np.int32)
               for _ in range(n_req)]

    common = dict(max_len=96, slots=4)
    res = {}
    for label, scfg in (
            ("reference", ServeConfig(fused=False, **common)),
            ("fused", ServeConfig(fused=True, sync_every=sync_every,
                                  **common))):
        res[label] = _bench_engine(params, cfg, scfg, prompts, max_new,
                                   reps=2)
        res[label].pop("_tokens")
        emit(f"serving/engine/{label}",
             1e6 * res[label]["wall_s"] / max(res[label]["decoded_tokens"], 1),
             f"tok_per_s={res[label]['tok_per_s']:.1f};"
             f"p50_lat_s={res[label]['p50_lat_s']:.3f}")
    speedup = res["fused"]["tok_per_s"] / res["reference"]["tok_per_s"]
    emit("serving/engine/fused_speedup", 0.0, f"speedup={speedup:.2f}x")

    out = {"meta": {"arch": arch, "quick": quick, "n_req": n_req,
                    "max_new": max_new, "sync_every": sync_every,
                    "slots": common["slots"], "max_len": common["max_len"],
                    "cpu_count": os.cpu_count(), "unix_time": time.time()},
           "reference": res["reference"], "fused": res["fused"],
           "speedup": speedup}
    if json_path:
        # keep the full-run numbers when a --quick smoke runs later: merge
        # under a mode key instead of clobbering the file
        mode = "quick" if quick else "full"
        write_bench_json(json_path, lambda prev: {**prev, mode: out})
    return out



def run_oversubscribe(quick: bool = False, json_path: str = JSON_PATH,
                      arch: str = "internlm2-1.8b", sync_every: int = 4):
    """KV oversubscription (PR 8): a session load whose full-concurrency
    working set is ~4x the KV pool.  With swap OFF the seed behavior
    applies — the allocator completes victims early as
    ``kv_pool_exhausted``.  With swap ON the engine preempts whole
    sessions to host memory and restores them block-exact, so the same
    pool sustains the load: every request completes ``max_new`` and the
    token streams match an ample-pool oracle."""
    import jax

    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig, make_engine_fns

    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(5)
    n_req = 8 if quick else 16
    plen, max_new, bs, slots, kv_blocks = 8, 16, 8, 8, 6
    prompts = [rng.randint(0, cfg.vocab, size=plen).astype(np.int32)
               for _ in range(n_req)]
    seq_blocks = -(-(plen + max_new) // bs)
    ratio = slots * seq_blocks / kv_blocks
    out = {"meta": {"arch": arch, "quick": quick, "n_requests": n_req,
                    "prompt_len": plen, "max_new": max_new,
                    "block_size": bs, "slots": slots,
                    "kv_blocks": kv_blocks,
                    "oversubscription": round(ratio, 2)}}

    def drain(scfg):
        eng = Engine(params, cfg, scfg,
                     shared_fns=make_engine_fns(cfg, scfg))
        t0 = time.perf_counter()
        reqs, peak = _drain_tracking_concurrency(eng, prompts, max_new)
        wall = time.perf_counter() - t0
        snap = eng.metrics.snapshot()
        toks = sum(len(r.out_tokens) for r in reqs)
        return {"wall_s": wall, "peak_concurrency": peak,
                "decoded_tokens": toks,
                "tok_per_s": toks / max(wall, 1e-9),
                "victims": sum(r.finish_reason == "kv_pool_exhausted"
                               for r in reqs),
                "swap_out": int(snap.get("engine.kv_swap_out", 0)),
                "swap_in": int(snap.get("engine.kv_swap_in", 0)),
                "swapped_blocks": int(snap.get("engine.kv_swapped_blocks",
                                               0)),
                "_tokens": [list(r.out_tokens) for r in reqs]}

    oracle = drain(ServeConfig(max_len=32, slots=slots,
                               sync_every=sync_every, paged=True,
                               block_size=bs, kv_blocks=64,
                               prefix_cache=False))
    base = drain(ServeConfig(max_len=32, slots=slots,
                             sync_every=sync_every, paged=True,
                             block_size=bs, kv_blocks=kv_blocks,
                             prefix_cache=True))
    swap = drain(ServeConfig(max_len=32, slots=slots,
                             sync_every=sync_every, paged=True,
                             block_size=bs, kv_blocks=kv_blocks,
                             prefix_cache=True, kv_swap=True))
    assert base["victims"] > 0, \
        "baseline must reproduce the seed's kv_pool_exhausted victims"
    assert swap["victims"] == 0, "swap must eliminate early completions"
    assert swap["swap_out"] > 0 and swap["swap_in"] == swap["swap_out"]
    assert swap["_tokens"] == oracle["_tokens"], \
        "swapped decode lost token parity with the ample-pool oracle"
    for label, res in (("oracle", oracle), ("swap_off", base),
                       ("swap_on", swap)):
        res.pop("_tokens")
        out[label] = res
        emit(f"serving/oversubscribe/{label}",
             1e6 * res["wall_s"] / max(res["decoded_tokens"], 1),
             f"tok_per_s={res['tok_per_s']:.1f};victims={res['victims']};"
             f"swaps={res['swap_out']}")
    emit("serving/oversubscribe/sustained_ratio", 0.0,
         f"ratio={ratio:.1f}x;swaps={swap['swap_out']};"
         f"victims_off={base['victims']}")
    if json_path:
        mode = "oversubscribe_quick" if quick else "oversubscribe"
        write_bench_json(json_path, lambda prev: {**prev, mode: out})
    return out


def run_overload(quick: bool = False, json_path: str = JSON_PATH,
                 arch: str = "internlm2-1.8b", sync_every: int = 4):
    """Sustained 2x overload with per-request deadlines (PR 9): requests
    arrive at twice the engine's measured service rate, each carrying a
    deadline budget.  *Shed-only* (admission bound, no brownout) keeps
    decoding full-length answers for requests whose deadlines are already
    doomed — the decode they consume counts for nothing.  *Brownout-on*
    climbs the graded ladder instead: halved ``max_new`` under pressure
    (L2) and a tightened admission bound (L3) convert that wasted decode
    into shorter answers that land inside their deadlines.

    The score is **goodput**: tokens of requests that completed OK within
    their deadline, per wall second.  The run asserts brownout-on beats
    shed-only by >= 1.2x — the graded-degradation claim, machine-checked.
    """
    import jax

    from repro.cluster import (AdmissionConfig, AdmissionController,
                               BrownoutController, EngineBackend,
                               MetricsRegistry, ReplicaConfig, Router,
                               Status)
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig, make_engine_fns

    cfg = reduced(get_config(arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    # decode-dominated requests (long max_new, short prompt) so that
    # brownout's halved max_new really halves the service time, and the
    # timescale (hundreds of fused steps per wave) dwarfs sleep jitter
    slots, max_new, plen = 4, 200, 16
    n_req = 16 if quick else 32
    scfg = ServeConfig(max_len=256, slots=slots, sync_every=sync_every)
    fns = make_engine_fns(cfg, scfg)
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab, size=plen).astype(np.int32)
               for _ in range(n_req)]

    def drive(brownout, bound, deadline_s, gap, reqs_payloads):
        metrics = MetricsRegistry()
        router = Router(
            metrics=metrics,
            admission=None if bound is None else AdmissionController(
                AdmissionConfig(max_queue_cost=bound), metrics),
            brownout=BrownoutController() if brownout else None)
        router.add_replica(
            EngineBackend(Engine(params, cfg, scfg, metrics=metrics,
                                 shared_fns=fns)),
            ReplicaConfig(max_batch=slots))
        t_start = time.perf_counter()
        reqs = []
        for pay in reqs_payloads:
            reqs.append(router.submit(pay, cost=max_new,
                                      timeout_s=deadline_s))
            if gap:
                time.sleep(gap)
        for q in reqs:
            router.wait(q, timeout=deadline_s + 60.0)
        wall = time.perf_counter() - t_start
        router.stop()
        snap = metrics.snapshot()
        by = {st: sum(q.status is st for q in reqs) for st in Status}
        good = sum(len(q.result) for q in reqs if q.status is Status.OK)
        return {"wall_s": wall, "goodput_tok_s": good / max(wall, 1e-9),
                "good_tokens": good, "ok": by[Status.OK],
                "expired": by[Status.EXPIRED],
                "shed": by[Status.REJECTED], "failed": by[Status.FAILED],
                "brownout_transitions":
                    int(snap.get("router.brownout_transitions", 0)),
                "deadline_expired_in_engine":
                    int(snap.get("engine.deadline_expired", 0))}

    # warm the *cluster-path* shapes (fresh engines later reuse the shared
    # jitted fns, but each prefill bucket the replica loop can form —
    # singleton, pair, full wave — must have compiled before timing), then
    # time one warm full-slot wave: the service unit every knob uses
    for batch in ((prompts[0],), prompts[:2], prompts[:slots]):
        drive(False, None, 600.0, 0.0, [(p, max_new) for p in batch])
    cal = drive(False, None, 600.0, 0.0,
                [(p, max_new) for p in prompts[:slots]])
    s_batch = cal["wall_s"]
    gap = s_batch / (slots * 2)          # 2x-overload inter-arrival
    deadline_s = 1.5 * s_batch           # one full-length wave fits; a
    #                                      request queued a wave deep dies
    bound = 8 * max_new                  # in-flight wave + one queued wave

    payloads = [(p, max_new) for p in prompts]
    shed_only = drive(False, bound, deadline_s, gap, payloads)
    browned = drive(True, bound, deadline_s, gap, payloads)
    ratio = browned["goodput_tok_s"] / max(shed_only["goodput_tok_s"], 1e-9)
    out = {"meta": {"arch": arch, "quick": quick, "n_requests": n_req,
                    "max_new": max_new, "slots": slots,
                    "overload_factor": 2.0,
                    "deadline_s": round(deadline_s, 3),
                    "arrival_gap_s": round(gap, 4)},
           "shed_only": shed_only, "brownout": browned,
           "goodput_ratio": round(ratio, 3)}
    for label, res in (("shed_only", shed_only), ("brownout", browned)):
        emit(f"serving/overload/{label}",
             1e6 * res["wall_s"] / max(res["good_tokens"], 1),
             f"goodput={res['goodput_tok_s']:.1f}tok/s;ok={res['ok']};"
             f"expired={res['expired']};shed={res['shed']}")
    emit("serving/overload/goodput_ratio", 0.0, f"ratio={ratio:.2f}x")
    assert browned["brownout_transitions"] >= 1, \
        "overload never tripped the brownout ladder — workload too light"
    assert ratio >= 1.2, \
        f"brownout goodput ratio {ratio:.2f}x below the 1.2x gate " \
        f"(on={browned['goodput_tok_s']:.1f} " \
        f"off={shed_only['goodput_tok_s']:.1f} tok/s)"
    if json_path:
        mode = "overload_quick" if quick else "overload"
        write_bench_json(json_path, lambda prev: {**prev, mode: out})
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (CI smoke)")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="K: fused decode steps per host sync")
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV scenarios: concurrent-session capacity "
                         "at fixed KV memory + shared-prefix cache workload")
    ap.add_argument("--oversubscribe", action="store_true",
                    help="KV oversubscription mode: 4x working set vs pool, "
                         "swap-off victims vs swap-on sustained sessions")
    ap.add_argument("--trace-overhead", action="store_true",
                    help="tracing-cost mode: identical fused workload with "
                         "the null tracer vs full span sampling")
    ap.add_argument("--telemetry-overhead", action="store_true",
                    help="telemetry-cost mode: identical fused workload "
                         "bare vs with the sampler + SLO engine + polled "
                         "HTTP stats endpoint live (recorded against the "
                         "2%% bound)")
    ap.add_argument("--overload", action="store_true",
                    help="overload-goodput mode: 2x sustained overload "
                         "with deadlines, brownout-on vs shed-only "
                         "(gated at a 1.2x goodput ratio)")
    args = ap.parse_args()
    if args.overload:
        run_overload(quick=args.quick, sync_every=args.sync_every)
    elif args.oversubscribe:
        run_oversubscribe(quick=args.quick)
    elif args.trace_overhead:
        run_trace_overhead(quick=args.quick, sync_every=args.sync_every)
    elif args.telemetry_overhead:
        run_telemetry_overhead(quick=args.quick,
                               sync_every=args.sync_every)
    elif args.paged:
        run_paged(quick=args.quick, sync_every=args.sync_every)
    else:
        run(quick=args.quick, sync_every=args.sync_every)
