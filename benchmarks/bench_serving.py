"""LM-serving throughput (continuous batching engine) on a reduced config:
tokens/sec and per-request latency — the MLaaS end of the paper's pipeline.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import api
from repro.serving import Engine, ServeConfig

from benchmarks.common import emit


def run(quick: bool = False):
    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    n_req = 4 if quick else 8
    eng = Engine(params, cfg, ServeConfig(max_len=96, slots=4))
    reqs = [eng.submit(rng.randint(0, cfg.vocab, size=8).astype(np.int32),
                       max_new=16) for _ in range(n_req)]
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    lat = [r.done_t - r.submit_t for r in reqs]
    emit("serving/continuous_batching", wall / max(toks, 1) * 1e6,
         f"tokens={toks};tok_per_s={toks/wall:.1f};p50_lat_s={np.median(lat):.3f}")


if __name__ == "__main__":
    run()
