"""Cluster scaling: throughput vs. replica count (1 -> 4) for the two
serving workloads, through the full front door (router + admission +
replica inboxes).

Workload model.  An MLaaS request is not just device compute: the paper's
service *reads each document from storage* (Gutenberg essays on disk/HDFS),
parses and featurizes it, and only then scores it.  That ingest stage is
host-side and blocking — so a single replica alternates ingest / compute,
and a replica pool overlaps one request's ingest with another's compute.
Ingest is modeled as a host stall of ``--ingest-ms`` per micro-batch
(``StreamBackend.fetch``) so the benchmark is reproducible.

Container caveat (same as ``benchmarks/common.py``): this box has 2 CPU
cores and XLA-CPU already parallelizes a *single* jitted call across them,
so added replicas cannot multiply raw device FLOPs here.  What scales — and
what this benchmark measures — is the end-to-end service path: ingest,
dispatch, and compute overlapped across replicas.  On real multi-host pools
the same harness also multiplies compute.

    PYTHONPATH=src python -m benchmarks.bench_cluster [--quick] [--lm]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.cluster import (AdmissionConfig, AdmissionController,
                           EngineBackend, MetricsRegistry, ReplicaConfig,
                           Router, Status, StreamBackend)
from repro.core.pipeline import PipelineConfig
from repro.core.stream import StreamConfig, StreamRuntime, make_stream_step
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus

from benchmarks.common import emit

REPLICAS = (1, 2, 4)


def _make_router(n_replicas: int, backend_factory, metrics, max_batch=4):
    router = Router(policy="least_loaded", metrics=metrics,
                    admission=AdmissionController(
                        AdmissionConfig(max_queue_cost=1 << 16), metrics))
    for _ in range(n_replicas):
        router.add_replica(backend_factory(),
                           ReplicaConfig(inbox_capacity=1024,
                                         max_batch=max_batch))
    return router


# ----------------------------------------------------------------------
def bench_svm_stream(n_mb: int, mb_size: int, ingest_s: float):
    pcfg = PipelineConfig(feat_dim=256, claim_capacity=64, evid_capacity=128)
    scfg = StreamConfig(period=1.0, capacity=mb_size, scope="window",
                        window=10.0, ring_capacity=512)
    models, _ = margot_models(pcfg)
    docs = synthetic_corpus(8, 64, seed=1)
    X, keys, _ = corpus_arrays(docs, dim=pcfg.feat_dim)
    shared_step = make_stream_step(pcfg, scfg)   # one compile for all pools

    rng = np.random.RandomState(0)

    def make_mb(i: int):
        idx = rng.randint(0, len(keys), mb_size)
        ts = i * scfg.period + np.linspace(0, scfg.period, mb_size,
                                           endpoint=False).astype(np.float32)
        return X[idx], keys[idx], ts

    def fetch(payload):                      # the storage read + parse stage
        if ingest_s > 0:
            time.sleep(ingest_s)
        return payload

    payloads = [make_mb(i) for i in range(n_mb)]
    results = {}
    for n in REPLICAS:
        metrics = MetricsRegistry()
        router = _make_router(
            n, lambda: StreamBackend(
                StreamRuntime(models, pcfg, scfg, step_fn=shared_step),
                fetch=fetch),
            metrics, max_batch=1)
        # warm the jit cache outside the timed window
        router.process_batch(payloads[:1], timeout_s=120.0)
        t0 = time.perf_counter()
        reqs = [router.submit(p, cost=mb_size, timeout_s=600.0)
                for p in payloads]
        outs = [router.wait(r, timeout=600.0) for r in reqs]
        wall = time.perf_counter() - t0
        router.stop()
        n_ok = sum(r.status is Status.OK for r in reqs)
        assert n_ok == len(payloads), f"{n_ok}/{len(payloads)} completed"
        tput = n_mb * mb_size / wall
        results[n] = tput
        snap = metrics.snapshot()
        emit(f"cluster/svm-stream/replicas={n}", 1e6 * wall / (n_mb * mb_size),
             f"tput={tput:.0f}inst/s speedup={tput / results[1]:.2f}x "
             f"p95={snap['router.latency_s.p95'] * 1e3:.0f}ms")
    return results


# ----------------------------------------------------------------------
def bench_lm_engine(n_requests: int, max_new: int, ingest_s: float):
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    from repro.serving.engine import make_engine_fns

    cfg = reduced(get_config("internlm2-1.8b"))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_len=64, slots=2)
    shared_fns = make_engine_fns(cfg, scfg)  # one compile for the whole pool
    rng = np.random.RandomState(0)
    # fixed prompt length -> a single prefill compile (shared cache)
    prompts = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(n_requests)]
    # warm the shared jit cache outside every timed window
    warm = Engine(params, cfg, scfg, shared_fns=shared_fns)
    warm.submit(prompts[0], max_new=2)
    warm.run_until_drained()

    class IngestEngineBackend(EngineBackend):
        def process(self, payloads):
            if ingest_s > 0:
                time.sleep(ingest_s * len(payloads))   # per-request ingest
            return super().process(payloads)

    results = {}
    for n in REPLICAS:
        metrics = MetricsRegistry()
        router = _make_router(
            n, lambda: IngestEngineBackend(
                Engine(params, cfg, scfg, metrics=metrics,
                       shared_fns=shared_fns)),
            metrics, max_batch=scfg.slots)
        t0 = time.perf_counter()
        reqs = [router.submit((p, max_new), cost=max_new, timeout_s=600.0)
                for p in prompts]
        outs = [router.wait(r, timeout=600.0) for r in reqs]
        wall = time.perf_counter() - t0
        router.stop()
        toks = sum(len(o) for o in outs if isinstance(o, list))
        tput = toks / wall
        results[n] = tput
        emit(f"cluster/lm-engine/replicas={n}", 1e6 * wall / max(toks, 1),
             f"tput={tput:.1f}tok/s speedup={tput / results[1]:.2f}x")
    return results


# ----------------------------------------------------------------------
def run(quick: bool = False, lm: bool = True, ingest_ms: float = 4.0):
    ingest_s = ingest_ms * 1e-3
    n_mb = 24 if quick else 64
    svm = bench_svm_stream(n_mb=n_mb, mb_size=256, ingest_s=ingest_s)
    if svm[4] < 2.0 * svm[1]:
        print(f"# WARNING: 4-replica speedup only "
              f"{svm[4] / svm[1]:.2f}x (target >= 2x)")
    if lm:
        bench_lm_engine(n_requests=8 if quick else 16,
                        max_new=4 if quick else 8, ingest_s=ingest_s)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-lm", dest="lm", action="store_false",
                    help="skip the LM engine sweep (per-replica jit compiles)")
    ap.add_argument("--ingest-ms", type=float, default=4.0,
                    help="modeled per-micro-batch document ingest stall")
    args = ap.parse_args()
    run(quick=args.quick, lm=args.lm, ingest_ms=args.ingest_ms)
