"""Cluster scaling: throughput vs. replica count (1 -> 4) for the two
serving workloads, through the full front door (router + admission +
replica inboxes), under both replica transports.

Workload model.  An MLaaS request is not just device compute: the paper's
service *reads each document from storage* (Gutenberg essays on disk/HDFS),
parses and featurizes it, and only then scores it.  That ingest stage is
host-side and blocking — so a single replica alternates ingest / compute,
and a replica pool overlaps one request's ingest with another's compute.
Ingest is modeled as a host stall of ``--ingest-ms`` per micro-batch
(``StreamBackend.fetch``) so the benchmark is reproducible.

Transports.  ``thread`` replicas share one Python process and one JAX
runtime: what scales is the ingest/dispatch/compute *overlap*, not device
FLOPs (XLA-CPU already parallelizes a single jitted call across this box's
2 cores).  ``process`` replicas are spawned workers with RPC inboxes and
independent JAX runtimes — the configuration where adding replicas can
scale compute itself on real multi-core/TPU hosts.  ``socket`` replicas
are the same spec-rebuilt workers behind framed localhost TCP (the
multi-host configuration, measured here over loopback): the delta between
the ``process`` and ``socket`` columns is the wire cost of
network-transparent placement.  Comparing columns in
``BENCH_cluster.json`` is how the compute-scaling claim is tracked across
PRs.

    PYTHONPATH=src python -m benchmarks.bench_cluster [--quick] [--no-lm] \
        [--transport {thread,process,socket,both,all}]

Machine-readable results land in ``BENCH_cluster.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.cluster import (AdmissionConfig, AdmissionController,
                           EngineBackend, MetricsRegistry, ReplicaConfig,
                           Router, Status, StreamBackend, engine_spec,
                           stream_spec)
from repro.core.pipeline import PipelineConfig
from repro.core.stream import StreamConfig, StreamRuntime, make_stream_step
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus

from benchmarks.common import bench_json_path, emit, write_bench_json

REPLICAS = (1, 2, 4)
JSON_PATH = bench_json_path("BENCH_cluster.json")


def _make_router(n_replicas: int, metrics, max_batch=4,
                 backend_factory=None, spec=None, transport="thread"):
    router = Router(policy="least_loaded", metrics=metrics,
                    admission=AdmissionController(
                        AdmissionConfig(max_queue_cost=1 << 16), metrics))
    rcfg = ReplicaConfig(inbox_capacity=1024, max_batch=max_batch)
    for _ in range(n_replicas):
        if transport in ("process", "socket"):
            router.add_replica(spec=spec, cfg=rcfg, transport=transport)
        else:
            router.add_replica(backend_factory(), rcfg)
    return router


# ----------------------------------------------------------------------
def bench_svm_stream(n_mb: int, mb_size: int, ingest_s: float,
                     transport: str = "thread", replicas=REPLICAS):
    pcfg = PipelineConfig(feat_dim=256, claim_capacity=64, evid_capacity=128)
    scfg = StreamConfig(period=1.0, capacity=mb_size, scope="window",
                        window=10.0, ring_capacity=512)
    docs = synthetic_corpus(8, 64, seed=1)
    X, keys, _ = corpus_arrays(docs, dim=pcfg.feat_dim)

    backend_factory = spec = None
    if transport in ("process", "socket"):
        # workers rebuild the runtime from config alone (their own compile,
        # their own JAX runtime) — the models derive deterministically
        spec = stream_spec(feat_dim=pcfg.feat_dim,
                           claim_capacity=pcfg.claim_capacity,
                           evid_capacity=pcfg.evid_capacity,
                           period=scfg.period, capacity=scfg.capacity,
                           scope=scfg.scope, window=scfg.window,
                           ring_capacity=scfg.ring_capacity,
                           ingest_ms=ingest_s * 1e3)
    else:
        models, _ = margot_models(pcfg)
        shared_step = make_stream_step(pcfg, scfg)  # one compile, all pools

        def fetch(payload):                  # the storage read + parse stage
            if ingest_s > 0:
                time.sleep(ingest_s)
            return payload

        def backend_factory():
            return StreamBackend(
                StreamRuntime(models, pcfg, scfg, step_fn=shared_step),
                fetch=fetch)

    rng = np.random.RandomState(0)

    def make_mb(i: int):
        idx = rng.randint(0, len(keys), mb_size)
        ts = i * scfg.period + np.linspace(0, scfg.period, mb_size,
                                           endpoint=False).astype(np.float32)
        return X[idx], keys[idx], ts

    payloads = [make_mb(i) for i in range(n_mb)]
    results = {}
    for n in replicas:
        metrics = MetricsRegistry()
        router = _make_router(n, metrics, max_batch=1,
                              backend_factory=backend_factory, spec=spec,
                              transport=transport)
        # warm every worker's jit cache outside the timed window (process
        # workers each own a compile; least_loaded spreads the warm batch)
        router.process_batch([payloads[0]] * n, timeout_s=300.0)
        t0 = time.perf_counter()
        reqs = [router.submit(p, cost=mb_size, timeout_s=600.0)
                for p in payloads]
        outs = [router.wait(r, timeout=600.0) for r in reqs]
        wall = time.perf_counter() - t0
        router.stop()
        n_ok = sum(r.status is Status.OK for r in reqs)
        assert n_ok == len(payloads), f"{n_ok}/{len(payloads)} completed"
        tput = n_mb * mb_size / wall
        results[n] = tput
        snap = metrics.snapshot()
        emit(f"cluster/svm-stream/{transport}/replicas={n}",
             1e6 * wall / (n_mb * mb_size),
             f"tput={tput:.0f}inst/s speedup={tput / results[min(results)]:.2f}x "
             f"p95={snap['router.latency_s.p95'] * 1e3:.0f}ms")
    return results


# ----------------------------------------------------------------------
def bench_lm_engine(n_requests: int, max_new: int, ingest_s: float,
                    transport: str = "thread", replicas=REPLICAS):
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import api
    from repro.serving import Engine, ServeConfig

    from repro.serving.engine import make_engine_fns

    arch = "internlm2-1.8b"
    cfg = reduced(get_config(arch))
    scfg = ServeConfig(max_len=64, slots=2)
    rng = np.random.RandomState(0)
    # fixed prompt length -> a single prefill compile (shared cache)
    prompts = [rng.randint(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(n_requests)]

    spec = backend_factory = None
    if transport in ("process", "socket"):
        spec = engine_spec(arch=arch, max_len=scfg.max_len, slots=scfg.slots,
                           reduce=True, seed=0, ingest_ms=ingest_s * 1e3)
    else:
        params, _ = api.init(jax.random.PRNGKey(0), cfg)
        shared_fns = make_engine_fns(cfg, scfg)  # one compile for the pool
        # warm the shared jit cache outside every timed window
        warm = Engine(params, cfg, scfg, shared_fns=shared_fns)
        warm.submit(prompts[0], max_new=2)
        warm.run_until_drained()

        class IngestEngineBackend(EngineBackend):
            def process(self, payloads):
                if ingest_s > 0:
                    time.sleep(ingest_s * len(payloads))  # per-request ingest
                return super().process(payloads)

        def backend_factory():
            return IngestEngineBackend(
                Engine(params, cfg, scfg, shared_fns=shared_fns))

    results = {}
    for n in replicas:
        metrics = MetricsRegistry()
        router = _make_router(n, metrics, max_batch=scfg.slots,
                              backend_factory=backend_factory, spec=spec,
                              transport=transport)
        if transport in ("process", "socket"):
            # per-worker prefill/decode compile happens on first contact
            router.process_batch([(prompts[0], 2)] * n, timeout_s=600.0)
        t0 = time.perf_counter()
        reqs = [router.submit((p, max_new), cost=max_new, timeout_s=600.0)
                for p in prompts]
        outs = [router.wait(r, timeout=600.0) for r in reqs]
        wall = time.perf_counter() - t0
        router.stop()
        toks = sum(len(o) for o in outs if isinstance(o, list))
        tput = toks / wall
        results[n] = tput
        emit(f"cluster/lm-engine/{transport}/replicas={n}",
             1e6 * wall / max(toks, 1),
             f"tput={tput:.1f}tok/s "
             f"speedup={tput / results[min(results)]:.2f}x")
    return results


# ----------------------------------------------------------------------
def run(quick: bool = False, lm: bool = True, ingest_ms: float = 4.0,
        transports=("thread", "process"), json_path: str = JSON_PATH):
    ingest_s = ingest_ms * 1e-3
    n_mb = 24 if quick else 64
    replicas = (1, 2) if quick else REPLICAS
    # meta is keyed by transport (like the result sections) so a partial
    # run's parameters never misdescribe another transport's columns
    meta = {"quick": quick, "ingest_ms": ingest_ms, "n_mb": n_mb,
            "replicas": list(replicas), "cpu_count": os.cpu_count(),
            "unix_time": time.time()}
    out = {"meta": {tr: dict(meta) for tr in transports},
           "svm_stream": {}, "lm_engine": {}}
    for tr in transports:
        svm = bench_svm_stream(n_mb=n_mb, mb_size=256, ingest_s=ingest_s,
                               transport=tr, replicas=replicas)
        out["svm_stream"][tr] = {str(k): v for k, v in svm.items()}
        top = max(replicas)
        if not quick and svm[top] < 2.0 * svm[1]:
            print(f"# WARNING: {tr} {top}-replica speedup only "
                  f"{svm[top] / svm[1]:.2f}x (target >= 2x)")
        if lm:
            eng = bench_lm_engine(n_requests=8 if quick else 16,
                                  max_new=4 if quick else 8,
                                  ingest_s=ingest_s, transport=tr,
                                  replicas=replicas)
            out["lm_engine"][tr] = {str(k): v for k, v in eng.items()}
    if json_path:
        # merge into any existing file: a partial run (--quick, one
        # --transport) must update only its own columns, not clobber the
        # cross-transport trajectory this file exists to track
        def merge(prev):
            for sec in ("svm_stream", "lm_engine", "meta"):
                merged = dict(prev.get(sec, {}))
                merged.update(out[sec])
                out[sec] = merged
            return out

        out = write_bench_json(json_path, merge)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-lm", dest="lm", action="store_false",
                    help="skip the LM engine sweep (per-replica jit compiles)")
    ap.add_argument("--ingest-ms", type=float, default=4.0,
                    help="modeled per-micro-batch document ingest stall")
    ap.add_argument("--transport", default="both",
                    choices=("thread", "process", "socket", "both", "all"),
                    help="which replica transports to sweep (both = "
                         "thread+process; all adds socket)")
    args = ap.parse_args()
    trs = {"both": ("thread", "process"),
           "all": ("thread", "process", "socket")}.get(
        args.transport, (args.transport,))
    run(quick=args.quick, lm=args.lm, ingest_ms=args.ingest_ms,
        transports=trs)
