"""Benchmark entrypoint: ``python -m benchmarks.run [--quick]``.

One benchmark per paper figure (6a, 6b, 7a/7b, 8a/8b) plus the roofline
summary (from dry-run artifacts) and the serving engine.  Output CSV:
``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    args = ap.parse_args()

    from benchmarks import (bench_batch_scalability, bench_stream_rate,
                            bench_filter_fraction, bench_model_size,
                            bench_roofline, bench_serving, bench_cluster)
    suites = [
        ("bench_batch_scalability", bench_batch_scalability),
        ("bench_stream_rate", bench_stream_rate),
        ("bench_filter_fraction", bench_filter_fraction),
        ("bench_model_size", bench_model_size),
        ("bench_roofline", bench_roofline),
        # writes BENCH_serving.json at the repo root: fused vs reference
        # single-replica engine (the base every cluster number multiplies)
        ("bench_serving", bench_serving),
        # writes BENCH_cluster.json at the repo root (perf trajectory)
        ("bench_cluster", bench_cluster),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in suites:
        if args.only and args.only not in name:
            continue
        try:
            mod.run(quick=args.quick)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
