"""Paper Fig 6b (Test 1, stream): max sustainable input rate per join scope
(window sizes w and scope-file), found by ramping the rate until the
micro-batch processing time exceeds the period."""
from __future__ import annotations

import numpy as np

from repro.core.pipeline import PipelineConfig
from repro.core.stream import StreamConfig, StreamRuntime, find_sustainable_rate
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus

from benchmarks.common import emit

RATES = [200, 800, 3200, 12800, 25600, 51200, 102400]
WINDOWS = [1.0, 5.0, 25.0]           # scaled versions of w=100/1000/5000s
PERIOD = 0.25                        # scaled version of the paper's 100 s


def _series(scope: str, window: float, pcfg, models, X, keys, quick=False):
    scfg = StreamConfig(period=PERIOD, capacity=1024, scope=scope,
                        window=window, ring_capacity=1024)

    def mk():
        return StreamRuntime(models, pcfg, scfg)

    rng = np.random.RandomState(0)

    def gen(n, t0):
        idx = rng.randint(0, len(keys), n)
        ts = t0 + np.linspace(0, PERIOD, n, endpoint=False).astype(np.float32)
        return X[idx], keys[idx], ts

    rates = RATES[:3] if quick else RATES
    return find_sustainable_rate(mk, gen, rates=rates, mb_per_rate=4)


def run(quick: bool = False):
    pcfg = PipelineConfig(feat_dim=256, claim_capacity=128, evid_capacity=256)
    models, _ = margot_models(pcfg)
    docs = synthetic_corpus(8, 64, seed=1)
    X, keys, _ = corpus_arrays(docs, dim=pcfg.feat_dim)
    windows = WINDOWS[:1] if quick else WINDOWS
    for w in windows:
        rate = _series("window", w, pcfg, models, X, keys, quick)
        emit(f"fig6b/window={w}s", 1e6 / max(rate, 1e-9),
             f"max_rate={rate:.0f}/s")
    rate = _series("file", 0.0, pcfg, models, X, keys, quick)
    emit("fig6b/scope-file", 1e6 / max(rate, 1e-9), f"max_rate={rate:.0f}/s")


if __name__ == "__main__":
    run()
