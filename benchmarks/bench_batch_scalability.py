"""Paper Fig 6a (Test 1, batch): processing time vs dataset size x workers.

DS1..DS4 are scaled-down Gutenberg stand-ins (Table 1 ratios preserved:
~1 : 7 : 24 : 48 in sentence count).
"""
from __future__ import annotations

from repro.core.pipeline import PipelineConfig
from repro.data.text import margot_models

from benchmarks.common import emit, make_dataset, run_partitioned_batch, timed

DATASETS = {"DS1": 128, "DS2": 896, "DS3": 3072, "DS4": 6144}
WORKERS = (1, 2, 4, 8)


def run(quick: bool = False):
    pcfg = PipelineConfig(feat_dim=256, claim_capacity=64, evid_capacity=128)
    models, _ = margot_models(pcfg)
    datasets = dict(list(DATASETS.items())[:2]) if quick else DATASETS
    workers = WORKERS[:2] if quick else WORKERS
    for ds, n in datasets.items():
        X, keys = make_dataset(n, pcfg)
        for w in workers:
            # warm the jit for this partition shape
            run_partitioned_batch(models, X, keys, pcfg, w)
            n_links = [0]

            def job():
                n_links[0], _ = run_partitioned_batch(models, X, keys, pcfg, w)

            t = timed(job)
            emit(f"fig6a/{ds}/workers={w}", t * 1e6,
                 f"sentences={n};links={n_links[0]}")


if __name__ == "__main__":
    run()
