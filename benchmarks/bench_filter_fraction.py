"""Paper Fig 7 (Test 2): effect of the fraction of instances surviving the
phase-1 filter (the aggregation-bottleneck variable).  The paper varied the
MARGOT thresholds to pass 5/35/65/90% of sentences to phase 2; we calibrate
the SVM decision threshold to the same percentiles.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import PipelineConfig, make_batch_step
from repro.core.stream import StreamConfig, StreamRuntime, find_sustainable_rate
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus
from repro.models import svm as svm_mod

from benchmarks.common import emit, timed

FRACTIONS = (0.05, 0.35, 0.65, 0.90)
N_SENT = 2048


def calibrated_threshold(models, X, frac: float) -> float:
    sc = np.asarray(svm_mod.svm_score(models["claim"], jnp.asarray(X)))
    return float(np.quantile(sc, 1.0 - frac))


def run(quick: bool = False):
    fracs = FRACTIONS[:2] if quick else FRACTIONS
    docs = synthetic_corpus(N_SENT // 64, 64, seed=2)
    for frac in fracs:
        pcfg0 = PipelineConfig(feat_dim=256)
        models, _ = margot_models(pcfg0)
        X, keys, _ = corpus_arrays(docs, dim=256)
        thr = calibrated_threshold(models, X, frac)
        cap = int(N_SENT * frac * 1.3) + 8
        pcfg = PipelineConfig(feat_dim=256, claim_capacity=cap,
                              evid_capacity=cap, threshold=thr)
        step = make_batch_step(pcfg)
        Xj, kj = jnp.asarray(X), jnp.asarray(keys)
        out = step(models, Xj, kj)            # compile
        t = timed(lambda: step(models, Xj, kj).link_scores.block_until_ready())
        n_pairs = int(out.pair_valid.sum())
        emit(f"fig7a/frac={int(frac*100)}%", t * 1e6,
             f"pairs={n_pairs};dropped={int(out.n_dropped)}")

        # stream variant (Fig 7b)
        scfg = StreamConfig(period=0.25, capacity=512, scope="window",
                            window=2.0, ring_capacity=max(2 * cap, 256))
        pcfg_s = PipelineConfig(feat_dim=256, claim_capacity=min(cap, 256),
                                evid_capacity=min(cap, 256), threshold=thr)

        def mk():
            return StreamRuntime(models, pcfg_s, scfg)

        rng = np.random.RandomState(0)

        def gen(n, t0):
            idx = rng.randint(0, len(keys), n)
            ts = t0 + np.linspace(0, 0.25, n, endpoint=False).astype(np.float32)
            return X[idx], keys[idx], ts

        rate = find_sustainable_rate(mk, gen, rates=[400, 1600, 6400, 12800, 25600, 51200],
                                     mb_per_rate=3)
        emit(f"fig7b/frac={int(frac*100)}%", 1e6 / max(rate, 1e-9),
             f"max_rate={rate:.0f}/s")


if __name__ == "__main__":
    run()
