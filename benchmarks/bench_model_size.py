"""Paper Fig 8 / Table 2 (Test 3): impact of SVM model size (number of
support vectors) on batch time and stream rate.  M1/M2/M3 are scaled
versions of the paper's 7,085 / 18,604 / 30,363 support vectors.

The paper's (surprising) finding: model size has an insignificant effect.
On TPU-class hardware the same holds while the score matmul stays
memory/latency-bound — the derived column lets us check the trend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import PipelineConfig, init_models, make_batch_step
from repro.core.stream import StreamConfig, StreamRuntime, find_sustainable_rate
from repro.data.text import corpus_arrays, synthetic_corpus

from benchmarks.common import emit, timed

MODELS = {"M1": 709, "M2": 1860, "M3": 3036}
N_SENT = 1024


def run(quick: bool = False):
    sizes = dict(list(MODELS.items())[:2]) if quick else MODELS
    pcfg = PipelineConfig(feat_dim=256, claim_capacity=128, evid_capacity=256)
    docs = synthetic_corpus(N_SENT // 64, 64, seed=3)
    X, keys, _ = corpus_arrays(docs, dim=256)
    Xj, kj = jnp.asarray(X), jnp.asarray(keys)
    for name, n_sv in sizes.items():
        models, _ = init_models(jax.random.PRNGKey(0), pcfg, n_sv=n_sv)
        step = make_batch_step(pcfg)
        step(models, Xj, kj)                  # compile
        t = timed(lambda: step(models, Xj, kj).link_scores.block_until_ready())
        emit(f"fig8a/{name}", t * 1e6, f"n_sv={n_sv}")

        scfg = StreamConfig(period=0.25, capacity=512, scope="window",
                            window=2.0, ring_capacity=512)

        def mk():
            return StreamRuntime(models, pcfg, scfg)

        rng = np.random.RandomState(0)

        def gen(n, t0):
            idx = rng.randint(0, len(keys), n)
            ts = t0 + np.linspace(0, 0.25, n, endpoint=False).astype(np.float32)
            return X[idx], keys[idx], ts

        rate = find_sustainable_rate(mk, gen, rates=[400, 1600, 6400, 12800, 25600, 51200],
                                     mb_per_rate=3)
        emit(f"fig8b/{name}", 1e6 / max(rate, 1e-9),
             f"n_sv={n_sv};max_rate={rate:.0f}/s")


if __name__ == "__main__":
    run()
