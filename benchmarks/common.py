"""Shared benchmark utilities.

The paper's cluster had 126 nodes; this container has one CPU core, so
wall-clock *speedup* from added workers is not observable here — what these
benchmarks validate is the harness itself (partitioning, speculation,
sustainable-rate detection) and the workload *shape* trends (input size,
filter fraction, model size).  Scale behaviour on real hardware is covered
by the dry-run roofline (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fault import speculative_map
from repro.core.pipeline import PipelineConfig, extract_links, make_batch_step
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus


def timed(fn: Callable[[], object]) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


#: repo root — where the BENCH_*.json perf-trajectory files live
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_json_path(name: str) -> str:
    return os.path.join(REPO_ROOT, name)


#: entries kept in each BENCH_*.json ``history`` array (append-only,
#: oldest dropped first) — enough for ``scripts/bench_report.py`` trends
#: without letting the files grow unboundedly
HISTORY_CAP = 50


def write_bench_json(path: str, merge: Callable[[Dict], Dict]) -> Dict:
    """Merge-write a BENCH_*.json: read whatever is already there (absent or
    corrupt files degrade to ``{}``), let ``merge(prev)`` fold the new
    results in — so a partial run updates only its own columns instead of
    clobbering the trajectory the file exists to track — and write it back
    deterministically.

    Every write also appends one entry to the file's ``history`` array:
    a timestamp plus the new values of the top-level scenario keys this
    run changed.  ``scripts/bench_report.py`` turns those into per-metric
    trend lines and regression flags; the array is bounded at
    :data:`HISTORY_CAP` entries."""
    prev: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = {}
    out = merge(prev)
    history: List[Dict] = list(prev.get("history") or [])
    changed = {k: out[k] for k in out
               if k != "history" and out[k] != prev.get(k)}
    if changed:
        history.append({
            "at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "changed": changed,
        })
    out["history"] = history[-HISTORY_CAP:]
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")
    return out


def make_dataset(n_sentences: int, pcfg: PipelineConfig, seed: int = 0):
    spd = 40
    docs = synthetic_corpus(max(1, n_sentences // spd), spd, seed=seed)
    X, keys, _ = corpus_arrays(docs, dim=pcfg.feat_dim)
    return X[:n_sentences], keys[:n_sentences]


def run_partitioned_batch(models, X, keys, pcfg: PipelineConfig,
                          n_workers: int):
    """Paper Fig 6a setup: partition the corpus, run the two-phase pipeline
    per partition on a worker pool with straggler speculation.

    Partitions are aligned to DOCUMENT boundaries (the paper's join key), so
    the link set is invariant to the worker count; filter capacities scale
    with partition size (the paper's filter is exact)."""
    import dataclasses
    n = X.shape[0]
    psize = -(-n // n_workers)
    # doc-aligned cut points
    cuts = [0]
    for i in range(1, n):
        if keys[i] != keys[i - 1] and i - cuts[-1] >= psize:
            cuts.append(i)
    cuts.append(n)
    psize = max(cuts[j + 1] - cuts[j] for j in range(len(cuts) - 1))
    pcfg = dataclasses.replace(pcfg,
                               claim_capacity=max(pcfg.claim_capacity, psize),
                               evid_capacity=max(pcfg.evid_capacity, psize))
    step = make_batch_step(pcfg)
    parts = [(X[cuts[j]:cuts[j + 1]], keys[cuts[j]:cuts[j + 1]])
             for j in range(len(cuts) - 1)]

    def work(part):
        Xp, kp = part
        pad = psize - Xp.shape[0]
        if pad:
            Xp = np.pad(Xp, ((0, pad), (0, 0)))
            kp = np.pad(kp, ((0, pad),), constant_values=-1)
        out = step(models, jnp.asarray(Xp), jnp.asarray(kp))
        return len(extract_links(out))

    results, stats = speculative_map(work, parts, n_workers=n_workers)
    return sum(results), stats
