"""MARGOT stream service (the paper's §5.2 / Listing 3): micro-batched
stream with scope-window or scope-file link detection, checkpoint/replay,
and a rate ramp that reports the max sustainable input rate.

    PYTHONPATH=src python examples/argmining_stream.py --scope window
"""
import argparse

import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.pipeline import PipelineConfig
from repro.core.stream import StreamConfig, StreamRuntime, find_sustainable_rate
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scope", choices=["window", "file"], default="window")
    ap.add_argument("--window", type=float, default=5.0)
    ap.add_argument("--period", type=float, default=0.25)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_stream_ckpt")
    args = ap.parse_args()

    pcfg = PipelineConfig(feat_dim=512, claim_capacity=128, evid_capacity=256)
    scfg = StreamConfig(period=args.period, capacity=1024, scope=args.scope,
                        window=args.window, ring_capacity=1024)
    models, _ = margot_models(pcfg)
    docs = synthetic_corpus(8, 64, seed=1)
    X, keys, _ = corpus_arrays(docs, dim=pcfg.feat_dim)

    ck = Checkpointer(args.checkpoint_dir)
    rt = StreamRuntime(models, pcfg, scfg, checkpointer=ck, checkpoint_every=5)

    # steady stream at a modest rate
    rng = np.random.RandomState(0)
    t = 0.0
    for mb in range(10):
        n = 64
        idx = rng.randint(0, len(keys), n)
        ts = t + np.linspace(0, args.period, n, endpoint=False).astype(np.float32)
        sc, ok = rt.process_microbatch(X[idx], keys[idx], ts)
        st = rt.stats[-1]
        print(f"mb={st.mb_id:02d} n={st.n_in} busy={st.busy_s*1e3:6.1f}ms "
              f"links={st.n_links}")
        t += args.period

    # find the max sustainable rate (paper Fig 6b methodology)
    def mk():
        return StreamRuntime(models, pcfg, scfg)

    def gen(n, t0):
        idx = rng.randint(0, len(keys), n)
        ts = t0 + np.linspace(0, args.period, n, endpoint=False).astype(np.float32)
        return X[idx], keys[idx], ts

    rate = find_sustainable_rate(mk, gen, rates=[100, 400, 1600, 6400],
                                 mb_per_rate=3)
    print(f"max sustainable rate (scope={args.scope}): {rate:.0f} inst/s")
    print(f"checkpoints at: {ck.steps()} (latest={ck.latest_step()})")


if __name__ == "__main__":
    main()
