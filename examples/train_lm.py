"""Train a ~100M-param LM for a few hundred steps on CPU with the full
production stack: config system, AdamW + cosine schedule + clipping,
gradient accumulation, checkpointing + restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.configs.base import ScanGroup
from repro.data.text import synthetic_tokens
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim import adamw_init


def small_lm():
    """~100M params: 8L, d=512, standard dense decoder."""
    return get_config("internlm2-1.8b").replace(
        n_layers=8, groups=(ScanGroup(("A",), 8),),
        d_model=512, n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048,
        vocab=32_000, dtype="float32", param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = small_lm()
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, lr=3e-4, warmup=20,
                                      total=args.steps,
                                      accum_steps=args.accum))
    ck = Checkpointer(args.checkpoint_dir, async_save=True)
    start = 0
    if args.resume and ck.latest_step() is not None:
        state = ck.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = ck.latest_step()
        print(f"resumed from step {start}")

    data = synthetic_tokens(0, args.batch, args.seq, cfg.vocab,
                            n_batches=args.steps + 1)
    t0 = time.perf_counter()
    for i, tokens in enumerate(data):
        step = start + i
        if step >= args.steps:
            break
        params, opt, metrics = step_fn(params, opt, {"tokens": jnp.asarray(tokens)})
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} ({dt:.1f}s)")
        if args.checkpoint_every and step and step % args.checkpoint_every == 0:
            ck.save(step, {"params": params, "opt": opt})
    ck.save(args.steps, {"params": params, "opt": opt})
    ck.wait()
    print(f"done; checkpoints: {ck.steps()}")


if __name__ == "__main__":
    main()
