"""End-to-end MARGOT batch service (the paper's §5.1 / Listing 1-2):
corpus -> sentence split -> featurize -> phase-1 claim/evidence detection ->
filter -> per-document Cartesian join -> phase-2 link scoring -> links.

    PYTHONPATH=src python examples/argmining_batch.py --docs 6 --workers 4
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import (PipelineConfig, extract_links,
                                 make_batch_step)
from repro.core.fault import speculative_map
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=6)
    ap.add_argument("--sentences-per-doc", type=int, default=48)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--use-pair-kernel", action="store_true",
                    help="route phase 2 through the Pallas pair_score kernel")
    args = ap.parse_args()

    pcfg = PipelineConfig(feat_dim=512, claim_capacity=128, evid_capacity=256,
                          use_pair_kernel=args.use_pair_kernel)
    models, _ = margot_models(pcfg)
    docs = synthetic_corpus(args.docs, args.sentences_per_doc, seed=0)
    X, keys, sents = corpus_arrays(docs, dim=pcfg.feat_dim)
    print(f"{len(sents)} sentences across {args.docs} docs")

    step = make_batch_step(pcfg)
    n = len(sents)
    psize = -(-n // args.workers)
    parts = [(X[i:i + psize], keys[i:i + psize], i)
             for i in range(0, n, psize)]

    def work(part):
        Xp, kp, off = part
        pad = psize - Xp.shape[0]
        if pad:
            Xp = np.pad(Xp, ((0, pad), (0, 0)))
            kp = np.pad(kp, (0, pad), constant_values=-1)
        out = step(models, jnp.asarray(Xp), jnp.asarray(kp))
        return [(c + off, e + off, s) for c, e, s in extract_links(out)]

    t0 = time.perf_counter()
    results, stats = speculative_map(work, parts, n_workers=args.workers)
    links = [l for r in results for l in r]
    dt = time.perf_counter() - t0

    print(f"{len(links)} links in {dt:.2f}s on {args.workers} workers "
          f"(launched={stats.launched}, speculated={stats.speculated})")
    for c, e, s in sorted(links, key=lambda x: -x[2])[:5]:
        print(f"  [{s:+.2f}] claim: {sents[c][:48]!r:50} <- evidence: "
              f"{sents[e][:48]!r}")


if __name__ == "__main__":
    main()
