"""Serve a small LM with batched requests (continuous batching) — the
paper's MLaaS pattern applied to LM inference: prefill = phase-1 map,
batcher = aggregation, decode = post-aggregation map.

    PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import api
from repro.serving import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=[a for a in ARCH_IDS if a != "whisper-base"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=128, slots=args.slots))

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.randint(0, cfg.vocab, size=rng.randint(4, 12))
        reqs.append(eng.submit(prompt.astype(np.int32), max_new=args.max_new))

    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0

    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"arch={args.arch}: {len(reqs)} requests, {toks} tokens in "
          f"{wall:.2f}s ({toks / wall:.1f} tok/s, slots={args.slots})")
    for r in reqs:
        ttft = r.first_token_t - r.submit_t
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} "
              f"out={r.out_tokens[:6]}... ttft={ttft:.2f}s "
              f"total={r.done_t - r.submit_t:.2f}s")


if __name__ == "__main__":
    main()
