"""Quickstart: pick any assigned architecture (--arch), run a tiny
forward + train step + a few decode steps on CPU.

    PYTHONPATH=src python examples/quickstart.py --arch gemma3-4b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list(ARCH_IDS))
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"arch={cfg.name} family={cfg.family} (reduced: d={cfg.d_model}, "
          f"layers={cfg.n_layers})")

    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    batch = api.input_batch(cfg, "train", batch=2, seq=32)

    logits = api.forward_fn(params, cfg, batch)
    print("forward:", logits.shape, "finite:", bool(jnp.all(jnp.isfinite(logits))))

    loss, (ce, aux) = api.loss_fn(params, cfg, batch)
    print(f"loss={float(loss):.4f} (ce={float(ce):.4f}, aux={float(aux):.5f})")

    if cfg.family != "encdec":
        caches = api.init_caches(cfg, 2, 64)
        lg, caches = api.prefill_fn(params, cfg, batch, caches)
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        S = batch["tokens"].shape[1] + (batch.get("patches").shape[1]
                                        if "patches" in batch else 0)
        for t in range(4):
            step = {"tokens": tok, "pos": jnp.full((2,), S + t, jnp.int32)}
            lg, caches = api.decode_fn(params, cfg, step, caches)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
            print(f"decode step {t}: tokens={tok[:, 0].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
