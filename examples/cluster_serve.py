"""Multi-replica MLaaS end to end: router + admission control + autoscaler
over SVM stream replicas, under a bursty synthetic user load.

Shows the three cluster behaviours on one trace:
  1. a traffic burst drives queue depth up -> the autoscaler adds replicas;
  2. offered load beyond the admission bound is shed with an explicit
     ``Rejected`` result (no silent deadline misses);
  3. when the burst passes, idle replicas are drained back down.

Replica placement is pluggable: ``--transport process`` places each replica
in a spawned worker process (its own JAX runtime, RPC inbox) — the
autoscaler then scales *worker processes* with zero code change — and
``--transport socket`` puts the same worker behind a framed TCP connection
with a reconnect handshake (here over loopback; the identical worker runs
on any host via ``python -m repro.cluster.worker_main``).

    PYTHONPATH=src python examples/cluster_serve.py [--transport socket]
"""
import argparse
import time

import numpy as np

from repro.cluster import (AdmissionConfig, AdmissionController, Autoscaler,
                           AutoscalerConfig, MetricsRegistry, ReplicaConfig,
                           Router, Status, StreamBackend, stream_spec)
from repro.core.pipeline import PipelineConfig
from repro.core.stream import StreamConfig, StreamRuntime, make_stream_step
from repro.data.text import corpus_arrays, margot_models, synthetic_corpus


def main(transport: str = "thread"):
    pcfg = PipelineConfig(feat_dim=256, claim_capacity=64, evid_capacity=128)
    scfg = StreamConfig(period=1.0, capacity=128, scope="window", window=10.0)
    models, _ = margot_models(pcfg)
    docs = synthetic_corpus(6, 48, seed=2)
    X, keys, _ = corpus_arrays(docs, dim=pcfg.feat_dim)
    shared_step = make_stream_step(pcfg, scfg)

    metrics = MetricsRegistry()
    admission = AdmissionController(AdmissionConfig(max_queue_cost=24), metrics)
    router = Router(policy="least_loaded", admission=admission, metrics=metrics)
    rcfg = ReplicaConfig(inbox_capacity=64, max_batch=1)

    if transport in ("process", "socket"):
        # remote workers rebuild the runtime from this serializable spec
        def backend_factory():
            return stream_spec(feat_dim=pcfg.feat_dim,
                               claim_capacity=pcfg.claim_capacity,
                               evid_capacity=pcfg.evid_capacity,
                               capacity=scfg.capacity, window=scfg.window,
                               ingest_ms=10.0)
        router.add_replica(spec=backend_factory(), cfg=rcfg,
                           transport=transport)
    else:
        def backend_factory():
            rt = StreamRuntime(models, pcfg, scfg, step_fn=shared_step)
            return StreamBackend(rt, fetch=lambda p: (time.sleep(0.01), p)[1])
        router.add_replica(backend_factory(), rcfg)
    scaler = Autoscaler(
        router, backend_factory,
        AutoscalerConfig(min_replicas=1, max_replicas=4, scale_up_depth=4.0,
                         scale_down_depth=0.5, cooldown_s=0.2,
                         idle_ticks_to_drain=6, replica_cfg=rcfg),
        metrics=metrics, transport=transport)

    rng = np.random.RandomState(0)

    def make_mb(i):
        idx = rng.randint(0, len(keys), scfg.capacity)
        ts = np.full(scfg.capacity, float(i), np.float32)
        return X[idx], keys[idx], ts

    router.process_batch([make_mb(0)], timeout_s=60.0)     # compile warmup

    # phase 1: burst — offer far more than the admission bound absorbs
    reqs = [router.submit(make_mb(i), timeout_s=60.0) for i in range(60)]
    for _ in range(12):
        ev = scaler.tick()
        if ev:
            print(f"  scale {ev.action} -> {ev.n_replicas} ({ev.reason})")
        time.sleep(0.05)
    done = [router.wait(r, timeout=60.0) for r in reqs]

    ok = sum(r.status is Status.OK for r in reqs)
    shed = sum(r.status is Status.REJECTED for r in reqs)
    print(f"burst: ok={ok} shed={shed} replicas={router.n_alive()}")

    # phase 2: calm — idle ticks drain the pool back down
    for _ in range(30):
        ev = scaler.tick()
        if ev:
            print(f"  scale {ev.action} -> {ev.n_replicas} ({ev.reason})")
        time.sleep(0.05)
    print(f"calm: replicas={router.n_alive()}")

    snap = metrics.snapshot()
    for k in ("router.completed", "admission.shed_queue_full",
              "router.shed_backpressure", "autoscaler.scale_ups",
              "autoscaler.scale_downs", "router.latency_s.p95"):
        print(f"  {k} = {snap.get(k, 0):.4g}")
    router.stop()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--transport", default="thread",
                    choices=("thread", "process", "socket"))
    main(transport=ap.parse_args().transport)
