#!/usr/bin/env sh
# Tier-1 verify (see ROADMAP.md), runnable from a fresh checkout:
#   sh scripts/run_tests.sh [extra pytest args...]
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
