"""Render the §Roofline markdown table from results/dryrun/*.json."""
import glob
import json
import os
import sys

ORDER_SHAPE = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main(res_dir="results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(res_dir, "*.json"))):
        d = json.load(open(f))
        rows.append(d)
    rows.sort(key=lambda d: (d["arch"], ORDER_SHAPE.index(d["shape"])
                             if d["shape"] in ORDER_SHAPE else 9,
                             d["policy"], d["mesh"]))
    print("| arch | shape | mesh | policy | t_compute | t_memory | t_coll "
          "| dominant | useful | fit (arg+temp GB) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        if d.get("skipped"):
            print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | — "
                  f"| SKIP | — | ({d['reason'][:40]}…) |")
            continue
        if not d.get("ok"):
            print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['policy']} "
                  f"| FAIL | | | | | {d['error'][:40]} |")
            continue
        fit = (d["arg_bytes_dev"] + d["temp_bytes_dev"]) / 1e9
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['policy']} "
              f"| {d['t_compute']:.4f} | {d['t_memory']:.4f} "
              f"| {d['t_collective']:.4f} | {d['dominant']} "
              f"| {d['useful_ratio']:.2f} | {fit:.1f} |")


if __name__ == "__main__":
    main(*sys.argv[1:])
