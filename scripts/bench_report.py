"""Render the perf trajectory recorded in BENCH_*.json history arrays.

    python scripts/bench_report.py [--strict] [FILES...]

Every ``benchmarks.common.write_bench_json`` call appends a timestamped
entry of the scenario keys it changed to the file's ``history`` array
(bounded at ``HISTORY_CAP``).  This script flattens those entries into
per-metric trend lines for the throughput-bearing metrics (``tok_per_s``,
``goodput_tok_s``, ratio and overhead fractions), prints a trend table,
and flags any metric whose latest throughput sample dropped more than
10% below the previous one.  ``--strict`` exits non-zero when a
regression is flagged (the default only reports, since single-box CI
timing is noisy).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Any, Dict, List, Tuple

REGRESSION_FRAC = 0.10
#: metrics where a drop is a regression (higher is better)
THROUGHPUT_SUFFIXES = ("tok_per_s", "goodput_tok_s", "speedup",
                       "capacity_ratio", "goodput_ratio",
                       "paged_vs_dense_tok_ratio",
                       "spec_effective_tok_ratio", "accept_rate",
                       "prefix_hit_rate")
#: metrics reported but not direction-flagged (lower is better / bounded)
INFO_SUFFIXES = ("overhead_frac", "overhead_frac_sampled", "p50_lat_s",
                 "wall_s")


def _flatten(prefix: str, node: Any, out: Dict[str, float]) -> None:
    if isinstance(node, dict):
        for k, v in node.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix] = float(node)


def _interesting(path: str) -> str:
    """'' if the metric is noise; 'throughput' or 'info' otherwise."""
    leaf = path.rsplit(".", 1)[-1]
    if leaf in THROUGHPUT_SUFFIXES:
        return "throughput"
    if leaf in INFO_SUFFIXES:
        return "info"
    return ""


def trends(path: str) -> Dict[str, List[Tuple[str, float]]]:
    """metric path -> [(timestamp, value), ...] across the history."""
    try:
        doc = json.load(open(path))
    except (OSError, ValueError):
        return {}
    series: Dict[str, List[Tuple[str, float]]] = {}
    for entry in doc.get("history") or []:
        flat: Dict[str, float] = {}
        _flatten("", entry.get("changed", {}), flat)
        for k, v in flat.items():
            if _interesting(k):
                series.setdefault(k, []).append((entry.get("at", "?"), v))
    return series


def report(paths: List[str], strict: bool = False) -> int:
    regressions = []
    any_rows = False
    for path in paths:
        series = trends(path)
        if not series:
            continue
        any_rows = True
        print(f"\n== {os.path.basename(path)} ==")
        print(f"{'metric':<58}{'n':>3}{'first':>12}{'last':>12}"
              f"{'delta':>9}")
        for metric in sorted(series):
            pts = series[metric]
            first, last = pts[0][1], pts[-1][1]
            delta = (last - first) / first if first else 0.0
            flag = ""
            if len(pts) >= 2 and _interesting(metric) == "throughput":
                prev = pts[-2][1]
                if prev > 0 and last < (1.0 - REGRESSION_FRAC) * prev:
                    flag = "  << REGRESSION " \
                           f"(-{(1.0 - last / prev) * 100:.0f}% vs prev)"
                    regressions.append((path, metric, prev, last))
            print(f"{metric:<58}{len(pts):>3}{first:>12.3f}{last:>12.3f}"
                  f"{delta * 100:>8.1f}%{flag}")
    if not any_rows:
        print("no history recorded yet — run any benchmarks/ module to "
              "start the trajectory")
    if regressions:
        print(f"\n{len(regressions)} throughput regression(s) flagged "
              f"(>{REGRESSION_FRAC * 100:.0f}% drop vs previous sample)")
        return 1 if strict else 0
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json files (default: repo root glob)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when a regression is flagged")
    args = ap.parse_args()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = args.files or sorted(glob.glob(os.path.join(root,
                                                        "BENCH_*.json")))
    sys.exit(report(files, strict=args.strict))
