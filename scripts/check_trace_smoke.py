"""Validate the trace-smoke / dashboard-smoke artifacts (CI).

    PYTHONPATH=src python scripts/check_trace_smoke.py trace.json prom.txt
    PYTHONPATH=src python scripts/check_trace_smoke.py --stats PREFIX

Asserts the Chrome trace-event JSON from a traced serve run is
schema-valid and forms *connected* span trees covering every hot-path
stage — parent-side (admission, router, transport) and worker-side
(replica batch, engine prefill/decode), the latter proving spans crossed
the socket boundary over heartbeats — and that the Prometheus text
exposition parses with internally consistent histogram series.

``--stats PREFIX`` validates a ``serve --stats-dump PREFIX`` artifact
set instead: ``PREFIX.metrics.txt`` must prom-parse, the
``PREFIX.timeseries.json`` schema must hold its documented memory bound,
``PREFIX.slo.json`` must carry well-formed burn-rate alert states, and
``PREFIX.dash.html`` must contain rendered sparkline SVGs and the table
view with no non-finite coordinates.
"""
import json
import math
import re
import sys

REQUIRED_STAGES = {
    "request", "admission.decide", "router.dispatch", "transport.inflight",
    "replica.batch", "engine.request", "engine.admit", "engine.prefill",
    "engine.decode_sync",
}
SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [0-9eE+.inf-]+$')


def check_chrome(path: str) -> None:
    doc = json.load(open(path))
    assert isinstance(doc.get("traceEvents"), list), "no traceEvents array"
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert xs, "no complete ('X') span events"
    for e in xs:
        missing = {"name", "ph", "ts", "dur", "pid", "tid", "args"} - set(e)
        assert not missing, f"event missing {missing}: {e}"
        assert e["dur"] >= 0, f"negative duration: {e}"
    names = {e["name"] for e in xs}
    missing = REQUIRED_STAGES - names
    assert not missing, f"hot-path stages absent from trace: {missing}"
    # connectivity: every parent pointer resolves, one root per trace
    ids = {e["args"]["span_id"] for e in xs}
    by_trace = {}
    for e in xs:
        a = e["args"]
        by_trace.setdefault(a["trace_id"], []).append(a)
        assert a["parent_id"] is None or a["parent_id"] in ids, \
            f"orphan span {a['span_id']} ({e['name']})"
    for tid, group in by_trace.items():
        roots = [a for a in group if a["parent_id"] is None]
        assert len(roots) == 1, f"trace {tid}: {len(roots)} roots"
    # worker spans run under their own pid track (cross-host timeline)
    assert len({e["pid"] for e in xs}) >= 2, \
        "expected parent + worker replica tracks"
    print(f"[trace-smoke] {path}: {len(xs)} spans, "
          f"{len(by_trace)} connected trees, "
          f"{len({e['pid'] for e in xs})} replica tracks")


def check_prom(path: str) -> None:
    text = open(path).read()
    lines = [ln for ln in text.strip().splitlines() if ln]
    assert lines, "empty exposition"
    series = {}
    for ln in lines:
        if ln.startswith("#"):
            assert re.match(r"^# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* "
                            r"(gauge|counter|histogram)$", ln) \
                or re.match(r"^# HELP [a-zA-Z_:][a-zA-Z0-9_:]* \S", ln), \
                f"bad comment line: {ln}"
            continue
        assert SAMPLE_RE.match(ln), f"unparseable sample line: {ln}"
        name, val = ln.rsplit(" ", 1)
        series[name] = float(val.replace("+Inf", "inf"))
    hist_stems = {n[:-len("_count")] for n in series
                  if n.endswith("_count")
                  and f'{n[:-len("_count")]}_bucket{{le="+Inf"}}' in series}
    assert hist_stems, "no histogram series in exposition"
    for stem in hist_stems:
        count = series[f"{stem}_count"]
        pairs = sorted(
            (float(re.search(r'le="([^"]+)"', n).group(1)
                   .replace("+Inf", "inf")), v)
            for n, v in series.items()
            if n.startswith(f"{stem}_bucket{{"))
        cums = [v for _, v in pairs]
        assert cums == sorted(cums), f"{stem}: non-cumulative buckets"
        assert pairs[-1][0] == float("inf") and pairs[-1][1] == count, \
            f"{stem}: +Inf bucket != count"
    print(f"[trace-smoke] {path}: {len(series)} series, "
          f"{len(hist_stems)} histograms consistent")


def check_timeseries(path: str) -> None:
    doc = json.load(open(path))
    for key in ("now", "windows", "n_keys", "n_points", "max_points",
                "dropped_keys", "counters", "gauges", "histograms"):
        assert key in doc, f"timeseries.json missing {key!r}"
    assert doc["n_points"] <= doc["max_points"], \
        f"memory bound violated: {doc['n_points']} > {doc['max_points']}"
    windows = [f"{w:g}s" for w in doc["windows"]]
    for key, c in doc["counters"].items():
        for w in windows:
            rate = c["rate"][w]
            assert math.isfinite(rate) and rate >= 0.0, \
                f"{key}: bad rate {rate}"
    for stem, h in doc["histograms"].items():
        for w in windows:
            for field in ("count_rate", "p50", "p99", "mean"):
                v = h[field][w]
                assert math.isfinite(v) and v >= 0.0, \
                    f"{stem}.{field}[{w}]: bad value {v}"
            assert h["p50"][w] <= h["p99"][w], f"{stem}: p50 > p99"
    assert doc["histograms"], "no histogram stems sampled"
    print(f"[dash-smoke] {path}: {doc['n_keys']} keys, "
          f"{doc['n_points']}/{doc['max_points']} points, "
          f"{len(doc['histograms'])} histogram stems")


def check_slo(path: str) -> None:
    doc = json.load(open(path))
    assert isinstance(doc.get("objectives"), list), "no objectives"
    assert doc.get("ticks", 0) > 0, "SLO engine never ticked"
    n_alerts = 0
    for obj in doc["objectives"]:
        for sub, alert in obj["alerts"].items():
            assert sub in ("latency", "availability"), f"odd sub {sub}"
            assert alert["state"] in ("ok", "firing"), \
                f"bad alert state {alert['state']}"
            assert math.isfinite(alert["budget_remaining"])
            n_alerts += 1
    assert n_alerts, "no alerts evaluated"
    print(f"[dash-smoke] {path}: {n_alerts} alerts, "
          f"ticks={doc['ticks']}, pressure={doc['pressure']:.2f}")


def check_dash(path: str) -> None:
    html = open(path).read()
    assert "<svg" in html, "no inline SVG sparklines"
    assert "<table" in html, "no table view (a11y requirement)"
    assert "NaN" not in html and "Infinity" not in html, \
        "non-finite values leaked into markup"
    polys = re.findall(r'<polyline points="([^"]+)"', html)
    assert polys, "no sparkline polylines rendered"
    pt_re = re.compile(r"^-?\d+(\.\d+)?,-?\d+(\.\d+)?$")
    for poly in polys:
        for pt in poly.split():
            assert pt_re.match(pt), f"malformed coordinate {pt!r}"
    print(f"[dash-smoke] {path}: {html.count('<svg')} SVGs, "
          f"{len(polys)} polylines, table view present")


def check_stats(prefix: str) -> None:
    check_prom(f"{prefix}.metrics.txt")
    check_timeseries(f"{prefix}.timeseries.json")
    check_slo(f"{prefix}.slo.json")
    check_dash(f"{prefix}.dash.html")


if __name__ == "__main__":
    if sys.argv[1] == "--stats":
        check_stats(sys.argv[2])
        print("[dash-smoke] OK")
    else:
        trace_path, prom_path = sys.argv[1], sys.argv[2]
        check_chrome(trace_path)
        check_prom(prom_path)
        if len(sys.argv) > 4 and sys.argv[3] == "--stats":
            check_stats(sys.argv[4])
        print("[trace-smoke] OK")
