"""Validate the trace-smoke artifacts (CI `trace-smoke` job).

    PYTHONPATH=src python scripts/check_trace_smoke.py trace.json prom.txt

Asserts the Chrome trace-event JSON from a traced serve run is
schema-valid and forms *connected* span trees covering every hot-path
stage — parent-side (admission, router, transport) and worker-side
(replica batch, engine prefill/decode), the latter proving spans crossed
the socket boundary over heartbeats — and that the Prometheus text
exposition parses with internally consistent histogram series.
"""
import json
import re
import sys

REQUIRED_STAGES = {
    "request", "admission.decide", "router.dispatch", "transport.inflight",
    "replica.batch", "engine.request", "engine.admit", "engine.prefill",
    "engine.decode_sync",
}
SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [0-9eE+.inf-]+$')


def check_chrome(path: str) -> None:
    doc = json.load(open(path))
    assert isinstance(doc.get("traceEvents"), list), "no traceEvents array"
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert xs, "no complete ('X') span events"
    for e in xs:
        missing = {"name", "ph", "ts", "dur", "pid", "tid", "args"} - set(e)
        assert not missing, f"event missing {missing}: {e}"
        assert e["dur"] >= 0, f"negative duration: {e}"
    names = {e["name"] for e in xs}
    missing = REQUIRED_STAGES - names
    assert not missing, f"hot-path stages absent from trace: {missing}"
    # connectivity: every parent pointer resolves, one root per trace
    ids = {e["args"]["span_id"] for e in xs}
    by_trace = {}
    for e in xs:
        a = e["args"]
        by_trace.setdefault(a["trace_id"], []).append(a)
        assert a["parent_id"] is None or a["parent_id"] in ids, \
            f"orphan span {a['span_id']} ({e['name']})"
    for tid, group in by_trace.items():
        roots = [a for a in group if a["parent_id"] is None]
        assert len(roots) == 1, f"trace {tid}: {len(roots)} roots"
    # worker spans run under their own pid track (cross-host timeline)
    assert len({e["pid"] for e in xs}) >= 2, \
        "expected parent + worker replica tracks"
    print(f"[trace-smoke] {path}: {len(xs)} spans, "
          f"{len(by_trace)} connected trees, "
          f"{len({e['pid'] for e in xs})} replica tracks")


def check_prom(path: str) -> None:
    text = open(path).read()
    lines = [ln for ln in text.strip().splitlines() if ln]
    assert lines, "empty exposition"
    series = {}
    for ln in lines:
        if ln.startswith("#"):
            assert re.match(r"^# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* "
                            r"(gauge|counter|histogram)$", ln), \
                f"bad comment line: {ln}"
            continue
        assert SAMPLE_RE.match(ln), f"unparseable sample line: {ln}"
        name, val = ln.rsplit(" ", 1)
        series[name] = float(val.replace("+Inf", "inf"))
    hist_stems = {n[:-len("_count")] for n in series
                  if n.endswith("_count")
                  and f'{n[:-len("_count")]}_bucket{{le="+Inf"}}' in series}
    assert hist_stems, "no histogram series in exposition"
    for stem in hist_stems:
        count = series[f"{stem}_count"]
        pairs = sorted(
            (float(re.search(r'le="([^"]+)"', n).group(1)
                   .replace("+Inf", "inf")), v)
            for n, v in series.items()
            if n.startswith(f"{stem}_bucket{{"))
        cums = [v for _, v in pairs]
        assert cums == sorted(cums), f"{stem}: non-cumulative buckets"
        assert pairs[-1][0] == float("inf") and pairs[-1][1] == count, \
            f"{stem}: +Inf bucket != count"
    print(f"[trace-smoke] {path}: {len(series)} series, "
          f"{len(hist_stems)} histograms consistent")


if __name__ == "__main__":
    trace_path, prom_path = sys.argv[1], sys.argv[2]
    check_chrome(trace_path)
    check_prom(prom_path)
    print("[trace-smoke] OK")
