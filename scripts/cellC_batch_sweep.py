"""Cell C iteration: decode batch sensitivity for qwen3-moe decode.

Hypothesis: after the cache/dispatch fixes the cell is memory-bound on
*expert weight streaming*, which is amortized by decode batch size:
t_memory/token should fall ~linearly in B until compute catches up.
(The assigned shape B=128 stays the reported cell; this sweep informs the
serving engine's slot count — the paper's partition-size trade-off.)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import jax

from repro.configs.base import ShapeCase, SHAPE_BY_NAME
from repro.launch import dryrun_lib
from repro.launch.mesh import make_production_mesh


def main():
    mesh = make_production_mesh()
    for B in (128, 512, 2048):
        sc = ShapeCase(f"decode_32k_b{B}", 32_768, B, "decode")
        SHAPE_BY_NAME[sc.name] = sc
        res = dryrun_lib.run_cell("qwen3-moe-30b-a3b", sc.name, mesh,
                                  policy="tp", skip_memory_pass=True)
        if not res.ok:
            print(f"B={B} FAIL {res.error[:200]}")
            continue
        tok_dev = B / 256
        print(f"B={B:5d}: t_c {res.t_compute:.5f} t_m {res.t_memory:.5f} "
              f"t_x {res.t_collective:.5f} dom {res.dominant} "
              f"t_m/token {res.t_memory / B * 1e6:.1f}us "
              f"wire/dev {res.coll_wire_bytes_dev/1e6:.0f}MB")


if __name__ == "__main__":
    main()
